"""Scalar vs. batched path parity for the baseline KVSs.

``get``, ``get_batch`` and the isolated ``mn_get_batch`` must agree on
values (hits AND misses) and on the per-op protocol accounting — the
batched paths are what the throughput figures time, the scalar paths are
what the protocol walkthroughs document, and the meter is what the
transport simulator replays, so a silent divergence would skew every
downstream number.
"""

import numpy as np
import pytest

from repro.core.baselines import ClusterKVS, DummyKVS, MicaKVS, RaceKVS
from repro.core.hashing import hash_range, split_u64, splitmix64
from repro.core.store import make_uniform_keys

N = 20_000
ABSENT = splitmix64(np.arange(1, 257, dtype=np.uint64) + np.uint64(1 << 45))


@pytest.fixture(scope="module")
def data():
    keys = make_uniform_keys(N, 7)
    return keys, splitmix64(keys)


@pytest.mark.parametrize("cls", [RaceKVS, MicaKVS, ClusterKVS])
def test_scalar_vs_batch_values_hits_and_misses(cls, data):
    keys, vals = data
    kvs = cls(keys, vals)
    present = keys[:512]
    q = np.concatenate([present, ABSENT])
    v_lo, v_hi, match = kvs.get_batch(q)
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | \
        np.asarray(v_lo).astype(np.uint64)
    match = np.asarray(match)
    for i, k in enumerate(q):
        scalar = kvs.get(int(k))
        if i < 512:
            assert match[i] and scalar == int(vals[i]) == int(got[i])
        else:
            assert scalar is None and not match[i]


@pytest.mark.parametrize("cls", [MicaKVS, ClusterKVS])
def test_mn_get_batch_matches_get_batch(cls, data):
    """The isolated MN kernel (what the MN-thread benchmarks time) returns
    exactly what the full batched path returns."""
    keys, vals = data
    kvs = cls(keys, vals)
    q = np.concatenate([keys[:1024], ABSENT])
    lo, hi = split_u64(q)
    if cls is MicaKVS:
        arrays = (kvs.fp, kvs.addr, kvs.h_klo, kvs.h_khi, kvs.h_vlo, kvs.h_vhi)
        b = hash_range(lo, hi, 0x111CA, kvs.nb).astype(np.int32)
        fp = RaceKVS._fp(lo, hi)
    else:
        arrays = (kvs.fp, kvs.addr, kvs.nxt,
                  kvs.h_klo, kvs.h_khi, kvs.h_vlo, kvs.h_vhi)
        b = hash_range(lo, hi, 0xC1C1, kvs.nb).astype(np.int32)
        fp = ClusterKVS._fp14(lo, hi)
    m_lo, m_hi, m_ok = kvs.mn_get_batch(b, fp, lo, hi, arrays)
    f_lo, f_hi, f_ok = kvs.get_batch(q)
    np.testing.assert_array_equal(np.asarray(m_ok), np.asarray(f_ok))
    ok = np.asarray(m_ok)
    np.testing.assert_array_equal(np.asarray(m_lo)[ok], np.asarray(f_lo)[ok])
    np.testing.assert_array_equal(np.asarray(m_hi)[ok], np.asarray(f_hi)[ok])
    assert ok[:1024].all() and not ok[1024:].any()


@pytest.mark.parametrize("cls,rts", [(RaceKVS, 2), (MicaKVS, 1),
                                     (ClusterKVS, 1), (DummyKVS, 1)])
def test_meter_counts_scalar_equals_batch(cls, rts, data):
    """Per-op round trips agree between the scalar protocol walk and the
    batched accounting (on clean hits — no fingerprint false positives)."""
    keys, vals = data
    kvs = cls(keys, vals)
    kvs.meter.reset()
    _ = kvs.get_batch(keys[:1024])
    batch = kvs.meter.per_op()
    assert batch["round_trips"] == rts
    kvs.meter.reset()
    hits = 0
    for k in keys[:256]:
        hits += kvs.get(int(k)) is not None
    scalar = kvs.meter.per_op()
    assert hits == 256
    # fp false positives may add the odd extra RT on the one-sided path
    assert scalar["round_trips"] == pytest.approx(rts, abs=0.1)
    # two-sided RPC responses are padded to MSG_BYTES in both directions;
    # one-sided READ payloads are raw in both
    if cls is RaceKVS:
        assert batch["req_bytes"] == 32 and batch["resp_bytes"] == 160
    elif cls is not DummyKVS:
        assert batch["req_bytes"] == 64 and batch["resp_bytes"] == 64
    # MN compute parity: the scalar walk and the batched kernel charge the
    # memory node in the same direction (zero stays zero)
    if cls is RaceKVS:
        assert scalar["mn_cmp_ops"] == batch["mn_cmp_ops"] == 0
    else:
        assert (scalar["mn_cmp_ops"] > 0) == (batch["mn_cmp_ops"] > 0)


# ----------------------------------------------- batched mutation parity
#
# The fixed-window batched mutation paths (vectorised probe/chain walks
# feeding the per-lane commit loop) must be *observationally identical*
# to the scalar loop: same results, byte-identical meter accounting, and
# the same final index + heap image — the staleness tracking (mutated
# buckets / dirty_all forcing a scalar re-walk) is exactly what makes
# that safe, so these tests lean on duplicate keys and mixed hits/misses
# to force those fallbacks.

def _index_arrays(kvs):
    arrays = [kvs.fp, kvs.addr, kvs.h_klo, kvs.h_khi, kvs.h_vlo, kvs.h_vhi]
    if hasattr(kvs, "nxt"):
        arrays.append(kvs.nxt)
    return arrays


def _assert_twins(a, b):
    assert a.meter.snapshot() == b.meter.snapshot()
    for x, y in zip(_index_arrays(a), _index_arrays(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _mutation_script(keys):
    """(kind, keys, values) steps mixing hits, misses, duplicate keys in
    one batch, re-inserts of live keys, and delete-then-reinsert."""
    fresh = splitmix64(np.arange(1, 129, dtype=np.uint64)
                       + np.uint64(1 << 47))
    dup = np.concatenate([keys[:64], keys[:64]])          # same key twice
    return [
        ("update", keys[:256], splitmix64(keys[:256] + np.uint64(1))),
        ("update", ABSENT[:64], splitmix64(ABSENT[:64])),  # all misses
        ("update", dup, splitmix64(dup + np.uint64(2))),   # last wins
        ("delete", keys[256:384], None),
        ("delete", np.concatenate([keys[300:332], keys[300:332]]), None),
        ("insert", fresh, splitmix64(fresh)),              # fresh keys
        ("insert", keys[256:320], splitmix64(keys[256:320])),  # re-insert
        ("insert", np.concatenate([fresh[:16], fresh[:16]]) + np.uint64(1),
         splitmix64(np.arange(32, dtype=np.uint64))),      # dup fresh
        ("update", keys[256:384], splitmix64(keys[256:384] + np.uint64(3))),
    ]


def _apply_batched(kvs, step):
    kind, ks, vs = step
    if kind == "update":
        return list(np.asarray(kvs.update_batch(ks, vs)))
    if kind == "delete":
        return list(np.asarray(kvs.delete_batch(ks)))
    return kvs.insert_batch(ks, vs)


def _apply_scalar(kvs, step):
    kind, ks, vs = step
    if kind == "update":
        return [kvs.update(int(k), int(v)) for k, v in zip(ks, vs)]
    if kind == "delete":
        return [kvs.delete(int(k)) for k in ks]
    return [kvs.insert(int(k), int(v)) for k, v in zip(ks, vs)]


@pytest.mark.parametrize("cls", [MicaKVS, ClusterKVS])
def test_batched_mutations_match_scalar_loop(cls, data):
    keys, vals = data
    # headroom for the script's fresh inserts (the displacement / chain
    # bounds are the engines' documented capacity contract, not parity's)
    batched = cls(keys, vals, load_factor=0.5)
    scalar = cls(keys, vals, load_factor=0.5)
    batched.meter.reset()
    scalar.meter.reset()
    for step in _mutation_script(keys):
        got = _apply_batched(batched, step)
        want = _apply_scalar(scalar, step)
        assert [bool(g) if not isinstance(g, str) else g for g in got] == \
            [bool(w) if not isinstance(w, str) else w for w in want], step[0]
        _assert_twins(batched, scalar)
    # both twins agree with ground truth afterwards
    q = np.concatenate([keys[:256], keys[256:320], keys[320:384],
                        ABSENT[:64]])
    b_lo, b_hi, b_ok = batched.get_batch(q)
    s_lo, s_hi, s_ok = scalar.get_batch(q)
    np.testing.assert_array_equal(np.asarray(b_ok), np.asarray(s_ok))
    np.testing.assert_array_equal(np.asarray(b_lo), np.asarray(s_lo))
    np.testing.assert_array_equal(np.asarray(b_hi), np.asarray(s_hi))
    ok = np.asarray(b_ok)
    assert ok[:256].all()          # updated keys still live
    assert ok[256:320].all()       # deleted-then-reinserted
    assert not ok[320:384].any()   # deleted, never reinserted
    assert not ok[384:].any()      # absent stays absent


@pytest.mark.parametrize("cls", [MicaKVS, ClusterKVS])
def test_batched_mutations_last_write_wins_in_offer_order(cls, data):
    keys, vals = data
    kvs = cls(keys, vals)
    k = keys[:32]
    dup = np.concatenate([k, k, k])
    v = np.concatenate([splitmix64(k + np.uint64(10)),
                        splitmix64(k + np.uint64(20)),
                        splitmix64(k + np.uint64(30))])
    ok = np.asarray(kvs.update_batch(dup, v))
    assert ok.all()
    for i, key in enumerate(k):
        got = kvs.get(int(key))
        assert got == int(v[64 + i])  # the batch's last occurrence wins
