"""Partition-tolerant cluster plane (ISSUE 9): network partitions, fenced
lease arbitration, per-shard HRW replica placement, and the seeded chaos
harness.

The contract under test, in order of importance:

* safety through a full cut — a fully-partitioned CN's shard leases are
  arbitrated to the survivors with a fencing-token bump; its post-heal
  stale-view write is rejected at the MN boundary (``fenced_writes``),
  re-routed on the refreshed view, and the final state converges
  bit-exactly to the host oracle on every CN;
* validation — fault events targeting undeployed CNs/MNs and
  overlapping same-kind/same-target windows are rejected at the
  ``FaultSchedule`` / ``StoreSpec`` / ``open_store`` / ``ClusterSpec``
  layers;
* placement — seeded HRW replica placement is deterministic, an MN
  crash resyncs only the shards placed on the crashed replica;
* chaos — :func:`repro.net.chaos.run_chaos` passes every invariant on
  three distinct seeds, and two runs of one seed are bit-identical in
  meter totals, final MN state, and exported telemetry;
* observability — per-kind ``faults{kind=...}`` counters reach the
  hubs, partition/fenced windows land on the Perfetto fault track;
* dormancy — the armed-but-empty plane (HRW + event-less schedule) is
  byte-identical to the plain PR 8 cluster.
"""

import json

import numpy as np
import pytest

from repro.api import SpecError, StoreSpec, open_store
from repro.api.registry import build_adapter
from repro.api.replication import ReplicaPlacement
from repro.cluster import ClusterSpec, cluster_of
from repro.net import FaultEvent, FaultSchedule, simulate, simulate_cluster
from repro.net.chaos import generate_chaos, run_chaos, state_signature
from repro.obs import chrome_trace, telemetry_rows
from repro.obs.hub import TelemetryConfig

_DEGRADED = ("backoff", "unavailable")


def _data(n, seed=9):
    rng = np.random.default_rng(seed)
    keys = rng.choice(2 ** 40, size=n, replace=False).astype(np.uint64)
    vals = rng.integers(1, 2 ** 50, size=n, dtype=np.uint64)
    return keys, vals, rng


def _part(at, dur, cn=1, mn=-1, down_s=1e-3):
    return FaultEvent("partition", at, dur, mn=mn, cn=cn, down_s=down_s)


# ---------------------------------------------------------------- validation
class TestValidation:
    def test_partition_event_shape(self):
        _part(10, 5).validate()                      # wildcard link ok
        _part(10, 5, mn=2).validate()                # specific link ok
        with pytest.raises(ValueError):              # needs an outage time
            FaultEvent("partition", 10, 5, mn=-1, cn=0).validate()
        with pytest.raises(ValueError):              # only partition gets -1
            FaultEvent("mn_crash", 10, 5, mn=-1, down_s=1e-3).validate()

    def test_cn_kinds_reject_mn_target(self):
        with pytest.raises(ValueError):
            FaultEvent("cn_delay", 10, 5, mn=1, cn=0,
                       extra_us=2.0).validate()
        FaultEvent("cn_delay", 10, 5, cn=1, extra_us=2.0).validate()
        with pytest.raises(ValueError):
            FaultEvent("cn_drop", 10, 5, cn=0, drop_rate=1.5).validate()

    def test_overlapping_windows_rejected(self):
        with pytest.raises(ValueError, match="overlap"):
            FaultSchedule(events=(_part(10, 20, mn=1),
                                  _part(25, 10, mn=1))).validate()
        # wildcard cut conflicts with any same-CN link window
        with pytest.raises(ValueError, match="overlap"):
            FaultSchedule(events=(_part(10, 20, mn=-1),
                                  _part(25, 10, mn=2))).validate()
        with pytest.raises(ValueError, match="overlap"):
            FaultSchedule(events=(
                FaultEvent("cn_drop", 10, 20, cn=1, drop_rate=0.1),
                FaultEvent("cn_drop", 15, 20, cn=1,
                           drop_rate=0.2))).validate()

    def test_disjoint_or_cross_target_windows_pass(self):
        FaultSchedule(events=(_part(10, 10, mn=1),
                              _part(30, 10, mn=1))).validate()   # sequential
        FaultSchedule(events=(_part(10, 20, cn=0, mn=1),
                              _part(15, 20, cn=1, mn=1))).validate()  # links
        FaultSchedule(events=(
            _part(10, 20, mn=1),
            FaultEvent("cn_drop", 12, 20, cn=1,
                       drop_rate=0.1))).validate()  # different kinds

    def test_storespec_rejects_undeployed_mn(self):
        spec = StoreSpec(kind="outback-dir", replicas=3,
                         faults=FaultSchedule(events=(_part(10, 5, mn=5),)))
        with pytest.raises(SpecError):
            spec.validate()

    def test_open_store_rejects_foreign_cn_targets(self):
        keys, vals, _ = _data(256)
        bad = StoreSpec(kind="outback-dir", replicas=2,
                        faults=FaultSchedule(events=(
                            FaultEvent("cn_drop", 10, 5, cn=1,
                                       drop_rate=0.2),)))
        with pytest.raises(SpecError, match="single CN"):
            open_store(bad, keys, vals)
        ok = StoreSpec(kind="outback-dir", replicas=2,
                       faults=FaultSchedule(events=(
                           FaultEvent("cn_drop", 10, 5, cn=0,
                                      drop_rate=0.2),)))
        open_store(ok, keys, vals)  # CN 0 is deployed

    def test_clusterspec_rejects_undeployed_cn(self):
        store = StoreSpec(kind="outback-dir", replicas=2,
                          faults=FaultSchedule(events=(_part(10, 5, cn=3),)))
        with pytest.raises(SpecError, match="CN 3"):
            ClusterSpec(store=store, n_cns=2).validate()
        ClusterSpec(store=store, n_cns=4).validate()

    def test_placement_spec_validation(self):
        with pytest.raises(SpecError):
            StoreSpec(kind="outback-dir", placement="rr").validate()
        with pytest.raises(SpecError):   # per-directory-shard property
            StoreSpec(kind="outback", placement="hrw").validate()
        with pytest.raises(SpecError):   # k exceeds the pool
            StoreSpec(kind="outback-dir", replicas=2, placement="hrw",
                      placement_k=3).validate()
        spec = StoreSpec(kind="outback-dir", replicas=3, placement="hrw",
                         placement_k=2)
        spec.validate()
        rt = StoreSpec.from_json_dict(spec.to_json_dict())
        assert rt.placement == "hrw" and rt.placement_k == 2


# ----------------------------------------------------------------- placement
class TestPlacement:
    def test_hrw_deterministic_k_subset(self):
        a = ReplicaPlacement(16, 4, 2, seed=3)
        b = ReplicaPlacement(16, 4, 2, seed=3)
        for s in range(16):
            m = a.members(s)
            assert m == b.members(s)
            assert len(m) == 2 == len(set(m))
            assert all(0 <= r < 4 for r in m)
        assert [a.members(s) for s in range(16)] \
            != [ReplicaPlacement(16, 4, 2, seed=4).members(s)
                for s in range(16)]
        for r in range(4):
            for s in a.shards_on(r):
                assert r in a.members(s)

    def test_split_successor_inherits_members(self):
        p = ReplicaPlacement(4, 3, 2, seed=1)
        p.extend_for_split(2)
        assert len(p) == 5
        assert p.members(4) == p.members(2)

    def test_mn_crash_resyncs_only_placed_shards(self):
        keys, vals, rng = _data(1500)
        sched = FaultSchedule.single_crash(300, 200, mn=1, seed=2,
                                           lease_term_ops=0)
        spec = StoreSpec(kind="outback-dir", replicas=3, placement="hrw",
                         placement_k=2, faults=sched, load_factor=0.5,
                         rng_seed=5, params={"initial_depth": 3})
        adapter, plane = build_adapter(spec, keys, vals)
        placed = set(adapter.placement.shards_on(1))
        assert placed and placed < set(range(len(adapter.placement)))

        installed = []
        for s, t in enumerate(adapter.replicas[1].engine.tables):
            orig = t.install_mn_state

            def spy(state, _orig=orig, _s=s):
                installed.append(_s)
                return _orig(state)

            t.install_mn_state = spy

        wk = rng.choice(keys, size=1200).astype(np.uint64)
        wv = rng.integers(1, 2 ** 50, size=1200, dtype=np.uint64)
        for i in range(0, 1200, 8):
            adapter.update_batch(wk[i:i + 8], wv[i:i + 8])
        assert adapter.meter_totals().resyncs > 0
        assert installed, "crash window closed without a per-shard resync"
        assert set(installed) == placed

        res = adapter.get_batch(keys[:256])
        assert res.found.all()


# --------------------------------------------------------- cluster fencing
def _fence_cluster(n=1200, rounds=1600, lanes=8, telemetry=False):
    keys, vals, rng = _data(n, seed=7)
    sched = FaultSchedule(
        events=(_part(rounds // 4, rounds // 3, cn=1, down_s=2e-3),),
        seed=3, lease_term_ops=0)
    spec = StoreSpec(kind="outback-dir", replicas=3, placement="hrw",
                     placement_k=2, faults=sched, load_factor=0.5,
                     rng_seed=5,
                     telemetry=TelemetryConfig() if telemetry else None)
    cl = cluster_of(spec, keys, vals, n_cns=2)
    oracle = dict(zip(keys.tolist(), vals.tolist()))
    wk = rng.choice(keys, size=rounds).astype(np.uint64)
    wv = rng.integers(1, 2 ** 50, size=rounds, dtype=np.uint64)
    acked_while_cut = 0
    for i in range(0, rounds, lanes):
        cn = (i // lanes) % 2
        ks, vs = wk[i:i + lanes], wv[i:i + lanes]
        cut_before = not cl.cn_reachable(cn)
        res = cl.cns[cn].update_batch(ks, vs)
        cut = cut_before and not cl.cn_reachable(cn)
        sts = res.statuses or ("ok",) * len(ks)
        for k, v, st in zip(ks.tolist(), vs.tolist(), sts):
            if st not in _DEGRADED:
                oracle[k] = v
                if cut:
                    acked_while_cut += 1
    for c in cl.cns:
        c.flush()
    return cl, keys, oracle, acked_while_cut


class TestClusterFencing:
    def test_full_cut_fences_then_converges(self):
        cl, keys, oracle, acked_while_cut = _fence_cluster()
        st = cl.stats
        assert acked_while_cut == 0, "split-brain acked writes"
        assert st.partition_arbitrations == 1
        assert st.fenced_write_lanes > 0
        assert st.fenced_rpcs >= 1
        assert st.view_syncs == 1
        assert cl.ledgers[1].fenced_writes == st.fenced_write_lanes
        assert cl.meter_totals().fenced_writes == st.fenced_write_lanes
        reasons = [h.reason for h in cl.handoffs]
        assert "partition" in reasons and "heal" in reasons
        # post-heal convergence: every CN serves the oracle bit-exactly
        for c in range(2):
            for i in range(0, len(keys), 64):
                ks = keys[i:i + 64]
                res = cl.cns[c].get_batch(ks)
                assert res.found.all()
                assert all(v == oracle[k] for k, v in
                           zip(ks.tolist(), res.values.tolist()))

    def test_single_link_cut_no_arbitration(self):
        keys, vals, rng = _data(900)
        sched = FaultSchedule(
            events=(_part(200, 300, cn=1, mn=1, down_s=1e-3),),
            seed=3, lease_term_ops=0)
        spec = StoreSpec(kind="outback-dir", replicas=3, placement="hrw",
                         placement_k=2, faults=sched, load_factor=0.5,
                         rng_seed=5)
        cl = cluster_of(spec, keys, vals, n_cns=2)
        oracle = dict(zip(keys.tolist(), vals.tolist()))
        wk = rng.choice(keys, size=1200).astype(np.uint64)
        wv = rng.integers(1, 2 ** 50, size=1200, dtype=np.uint64)
        for i in range(0, 1200, 8):
            cn = (i // 8) % 2
            ks, vs = wk[i:i + 8], wv[i:i + 8]
            res = cl.cns[cn].update_batch(ks, vs)
            sts = res.statuses or ("ok",) * len(ks)
            for k, v, st in zip(ks.tolist(), vs.tolist(), sts):
                if st not in _DEGRADED:
                    oracle[k] = v
        cl.cns[0].flush(), cl.cns[1].flush()
        assert cl.stats.partition_arbitrations == 0
        assert cl.stats.fenced_write_lanes == 0
        res = cl.cns[0].get_batch(keys)
        assert res.found.all()
        assert all(v == oracle[k]
                   for k, v in zip(keys.tolist(), res.values.tolist()))

    def test_replay_partition_per_link(self):
        cl, _keys, _oracle, _ = _fence_cluster(n=800, rounds=800)
        res = simulate_cluster([t.trace for t in cl.transports], replicas=3)
        parts = [w for w in res.fault_windows if w[2] == "partition"]
        fences = [w for w in res.fault_windows if w[2] == "fenced"]
        assert len(parts) == 1 and parts[0][3] == 1   # keyed by CN
        assert parts[0][1] - parts[0][0] == pytest.approx(2e-3)
        assert len(fences) == 1 and fences[0][0] == fences[0][1]
        # determinism of the replay itself
        res2 = simulate_cluster([t.trace for t in cl.transports], replicas=3)
        assert res.fault_windows == res2.fault_windows
        assert np.array_equal(res.latencies_us, res2.latencies_us)

    def test_single_store_partition_stalls_replay(self):
        keys, vals, rng = _data(600)
        sched = FaultSchedule(
            events=(_part(150, 200, cn=0, down_s=5e-3),),
            seed=1, lease_term_ops=0)
        spec = StoreSpec(kind="outback-dir", replicas=2, faults=sched,
                         load_factor=0.5, rng_seed=5)
        from repro.net import Transport
        tr = Transport()
        st = open_store(spec, keys, vals, transport=tr)
        for i in range(0, 800, 8):
            idx = rng.integers(0, len(keys), size=8)
            st.get_batch(keys[idx])
        st.flush()
        res = simulate(tr.trace, replicas=2)
        parts = [w for w in res.fault_windows if w[2] == "partition"]
        assert parts, "partition window missing from the replay"
        # a post-heal segment held at the CN: makespan covers the outage
        assert res.seconds >= 5e-3


# -------------------------------------------------------------------- chaos
class TestChaos:
    def test_generated_schedules_are_valid_and_sequential(self):
        for seed in range(6):
            sched = generate_chaos(seed, 2000)
            sched.validate()
            evs = sorted(sched.events, key=lambda e: e.at_op)
            for a, b in zip(evs, evs[1:]):
                assert a.at_op + a.duration_ops <= b.at_op
            assert evs[0].kind == "partition" and evs[0].mn == -1

    @pytest.mark.parametrize("seed", (1, 2, 3))
    def test_invariants_hold(self, seed):
        rep = run_chaos(seed, n_ops=1400, n_keys=600)
        assert rep.passed, rep.failures
        assert rep.lost_acked_writes == 0
        assert rep.split_brain_acked_writes == 0
        assert rep.linearizability_violations == 0
        assert rep.partition_arbitrations >= 1   # window 0 is a full cut
        assert rep.acked_writes > 0 and rep.heal_checks >= 1
        json.dumps(rep.to_json_dict())           # schema stays serialisable

    def test_same_seed_bit_identical(self):
        a = run_chaos(5, n_ops=1200, n_keys=500, telemetry=True)
        b = run_chaos(5, n_ops=1200, n_keys=500, telemetry=True)
        assert a.meters == b.meters
        assert a.state_sig == b.state_sig
        assert a.telemetry_sig == b.telemetry_sig
        rows_a = [r for h in a.cluster.hubs for r in telemetry_rows(h)]
        rows_b = [r for h in b.cluster.hubs for r in telemetry_rows(h)]
        assert json.dumps(rows_a, sort_keys=True) \
            == json.dumps(rows_b, sort_keys=True)
        da, db = a.to_json_dict(), b.to_json_dict()
        assert da == db


# ------------------------------------------------------------- observability
class TestTelemetry:
    def test_fault_kind_counters_single_store(self):
        keys, vals, rng = _data(600)
        sched = FaultSchedule(
            events=(FaultEvent("delay", 100, 80, extra_us=3.0),
                    FaultEvent("cn_drop", 260, 80, cn=0, drop_rate=0.2),
                    _part(420, 120, cn=0, mn=1)),
            seed=1, lease_term_ops=0)
        spec = StoreSpec(kind="outback-dir", replicas=2, faults=sched,
                         load_factor=0.5, telemetry=TelemetryConfig())
        st = open_store(spec, keys, vals)
        for _ in range(0, 700, 8):
            idx = rng.integers(0, len(keys), size=8)
            st.get_batch(keys[idx])
        st.flush()
        c = st.telemetry.counters
        assert c.get("faults{kind=delay}") == 1
        assert c.get("faults{kind=cn_drop}") == 1
        assert c.get("faults{kind=partition}") == 1

    def test_cluster_fence_counters_on_target_hub(self):
        cl, _keys, _oracle, _ = _fence_cluster(telemetry=True)
        merged = {}
        for h in cl.hubs:
            for k, v in h.counters.items():
                merged[k] = merged.get(k, 0) + v
        assert merged.get("faults{kind=partition}") == 1
        assert cl.hubs[1].counters.get("faults{kind=fenced}") == 1
        assert cl.hubs[1].counters.get("cluster.fenced_writes") \
            == cl.stats.fenced_write_lanes

    def test_chrome_trace_fault_track_has_partition(self):
        keys, vals, rng = _data(500)
        sched = FaultSchedule(events=(_part(100, 150, cn=0, down_s=2e-3),),
                              seed=1, lease_term_ops=0)
        spec = StoreSpec(kind="outback-dir", replicas=2, faults=sched,
                         load_factor=0.5)
        from repro.net import Transport
        tr = Transport()
        st = open_store(spec, keys, vals, transport=tr)
        for _ in range(0, 500, 8):
            idx = rng.integers(0, len(keys), size=8)
            st.get_batch(keys[idx])
        st.flush()
        doc = chrome_trace(tr.trace, replicas=2)
        slices = [e for e in doc["traceEvents"]
                  if e.get("pid") == 3 and e.get("name") == "partition"]
        assert slices and slices[0]["dur"] == pytest.approx(2e3)


# ------------------------------------------------------------------ dormancy
class TestDormant:
    def test_armed_empty_plane_is_byte_identical(self):
        keys, vals, rng = _data(1200, seed=11)
        plain = StoreSpec(kind="outback-dir", load_factor=0.85, rng_seed=2)
        armed = StoreSpec(kind="outback-dir", load_factor=0.85, rng_seed=2,
                          placement="hrw", placement_k=1,
                          faults=FaultSchedule(lease_term_ops=0))
        a = cluster_of(plain, keys, vals, n_cns=2)
        b = cluster_of(armed, keys, vals, n_cns=2)
        wk = rng.choice(keys, size=1000).astype(np.uint64)
        wv = rng.integers(1, 2 ** 50, size=1000, dtype=np.uint64)
        for i in range(0, 1000, 16):
            cn = (i // 16) % 2
            for cl in (a, b):
                cl.cns[cn].update_batch(wk[i:i + 16], wv[i:i + 16])
                cl.cns[1 - cn].get_batch(wk[i:i + 16])
        for cl in (a, b):
            for c in cl.cns:
                c.flush()
        assert a.meter_totals().snapshot() == b.meter_totals().snapshot()
        for i in range(2):
            assert a.transports[i].trace == b.transports[i].trace
        assert state_signature(a.mn_state()) == state_signature(b.mn_state())
        assert b.stats.partition_arbitrations == 0
        assert b.stats.fenced_write_lanes == 0
