"""Multi-CN plane (``repro.cluster``): elastic membership, shard-ownership
handoff, and cross-CN cache coherence.

The contract under test, in order of importance:

* dormant-plane contract #3 — a Cluster of N=1 with an empty membership
  schedule is **byte-identical** to the ``open_store`` path: same
  CommMeter totals, same recorded trace, same final MN state;
* coherence — two CNs interleaving writes and reads on the same shards,
  through a live §4.4 split, never serve a stale cached read (every
  answer matches a host-side oracle), and the whole run is deterministic
  across seeded reruns;
* handoff — a CN join/leave moves only the affected shards' CN half
  (DMPH seeds + othello arrays): bytes metered on the destination equal
  the moved shards' exact CN-half sizes, O(shards moved) not O(keys);
* elasticity — a crashed CN answers degraded and rejoins after its
  window; a clean leave loses zero acknowledged writes;
* the write-combining reconciliation satellite — combined reads whose
  buffered write fails are re-read, answers equal ``combine_reads=False``;
* the replay companion — ``simulate_cluster`` is deterministic and
  degenerates to ``simulate`` for one CN.
"""

import dataclasses
import pickle

import numpy as np
import pytest

from repro.api import BatchPolicy, SpecError, StoreSpec, open_store
from repro.cluster import (Cluster, ClusterSpec, MembershipEvent,
                           MembershipSchedule, OwnershipTable, ShardEpochs,
                           cluster_of)
from repro.net import (FaultEvent, FaultSchedule, Transport, simulate,
                       simulate_cluster)

N = 2048


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(9)
    keys = np.unique(rng.integers(1, 1 << 62, 2 * N + 512, dtype=np.uint64))
    assert len(keys) >= 2 * N
    vals = np.arange(1, len(keys) + 1, dtype=np.uint64)
    return keys[:N], vals[:N], keys[N:2 * N], vals[N:2 * N]


def _spec(**kw):
    kw.setdefault("cache_budget_bytes", 32 << 10)
    return StoreSpec(kind="outback-dir", **kw)


def _state_sig(x):
    if isinstance(x, dict):
        return tuple(sorted((k, _state_sig(v)) for k, v in x.items()
                            if k != "cn"))
    if isinstance(x, np.ndarray):
        return (x.dtype.str, x.shape, x.tobytes())
    if isinstance(x, (list, tuple)):
        return tuple(_state_sig(v) for v in x)
    return x


# ------------------------------------------------------- dormant contract

def test_single_cn_byte_identical_to_open_store(data):
    keys, vals, extra, evals = data
    t_ref = Transport()
    ref = open_store(_spec(), keys, vals, transport=t_ref)
    cl = cluster_of(_spec(), keys, vals, n_cns=1)
    cn = cl.cns[0]

    rng = np.random.default_rng(0)
    for step in range(6):
        idx = rng.integers(0, N, size=256)
        for st in (ref, cn):
            st.get_batch(keys[idx])
        if step % 2:
            nv = rng.integers(1, 1 << 32, size=64).astype(np.uint64)
            for st in (ref, cn):
                st.update_batch(keys[idx[:64]], nv)
    for st in (ref, cn):
        st.insert_batch(extra[:128], evals[:128])
        st.get(int(extra[0]))
        st.delete(int(extra[1]))

    assert ref.meter_totals().snapshot() == cl.meter_totals().snapshot()
    assert t_ref.trace == cl.transports[0].trace
    assert (pickle.dumps(_state_sig(ref.engine.mn_state()))
            == pickle.dumps(_state_sig(cl.mn_state())))
    # nothing cluster-only fired
    s = cl.stats.snapshot()
    assert s["forward_rpcs"] == 0 and s["handoffs"] == 0
    assert cl.epochs.stale_syncs == 0


# ---------------------------------------------------- coherence (property)

def _coherence_run(data, seed):
    """Two CNs interleave writes/reads on shared shards through a live
    split; returns (answers, meter snapshot, n_tables) for determinism
    comparison.  Asserts every answer against a host-side oracle."""
    keys, vals, extra, evals = data
    cl = cluster_of(_spec(load_factor=0.85), keys, vals, n_cns=2)
    oracle = {int(k): int(v) for k, v in zip(keys, vals)}
    rng = np.random.default_rng(seed)
    n_start = len(cl.engine.tables)
    answers = []
    ins = 0
    for step in range(24):
        writer, reader = cl.cns[step % 2], cl.cns[(step + 1) % 2]
        idx = rng.integers(0, N, size=96)
        # reader warms its cache on these exact keys...
        r = reader.get_batch(keys[idx])
        for k, v, f in zip(keys[idx], r.values, r.found):
            assert f and int(v) == oracle[int(k)]
        # ...then the *other* CN overwrites some of them
        nv = rng.integers(1, 1 << 32, size=32).astype(np.uint64)
        w = writer.update_batch(keys[idx[:32]], nv)
        for k, v, ok in zip(keys[idx[:32]], nv, w.found):
            if ok:
                oracle[int(k)] = int(v)
        # insert pressure drives organic §4.4 splits mid-run
        take = extra[ins:ins + 64]
        tv = evals[ins:ins + 64]
        ins += 64
        wi = writer.insert_batch(take, tv)
        for k, v, ok in zip(take, tv, wi.found):
            if ok:
                oracle[int(k)] = int(v)
        # the stale-read hunt: reader re-reads its (now invalid) hot set
        r2 = reader.get_batch(keys[idx])
        for k, v, f in zip(keys[idx], r2.values, r2.found):
            assert f, int(k)
            assert int(v) == oracle[int(k)], \
                f"stale read escaped the epoch check for key {int(k)}"
        answers.append((r2.values.copy(), r2.found.copy()))
    assert len(cl.engine.tables) > n_start, \
        "the scenario must drive a live split"
    assert cl.epochs.bumps > 0 and cl.stats.epoch_invalidations > 0
    return answers, cl.meter_totals().snapshot(), len(cl.engine.tables)


def test_two_cn_coherence_through_live_split(data):
    a1, m1, t1 = _coherence_run(data, seed=42)
    a2, m2, t2 = _coherence_run(data, seed=42)
    # seeded rerun: identical answers, identical meters, identical topology
    assert m1 == m2 and t1 == t2
    for (v1, f1), (v2, f2) in zip(a1, a2):
        assert (v1 == v2).all() and (f1 == f2).all()


def test_non_owner_write_forwards_and_owner_read_does_not(data):
    keys, vals, _, _ = data
    for seed in range(16):  # a seed where both CNs own shards
        cl = cluster_of(_spec(params={"initial_depth": 3}), keys, vals,
                        n_cns=2, membership=MembershipSchedule(seed=seed))
        if len(set(cl.ownership.owners)) == 2:
            break
    shards = cl.shards_of(keys)
    owners = cl.ownership.owners_for(shards)
    mine = keys[owners == 0][:64]
    theirs = keys[owners == 1][:64]
    assert len(mine) and len(theirs), "both CNs must own something"
    before = cl.stats.forward_rpcs
    cl.cns[0].get_batch(mine)  # owner-local: no forward RPC
    assert cl.stats.forward_rpcs == before
    cl.cns[0].update_batch(theirs, np.arange(1, len(theirs) + 1,
                                             dtype=np.uint64))
    assert cl.stats.forward_rpcs == before + 1  # one batched forward
    assert cl.stats.forwarded_write_lanes >= len(theirs)


# ----------------------------------------------------------------- handoff

def test_join_handoff_moves_only_affected_shard_bytes(data):
    keys, vals, _, _ = data
    sched = MembershipSchedule.single_join(at_op=512, cn=3,
                                           initial=(0, 1, 2), seed=7)
    cl = cluster_of(_spec(params={"initial_depth": 3}), keys, vals,
                    n_cns=4, membership=sched)
    led3_before = cl.ledgers[3].snapshot()["resp_bytes"]
    for i in range(8):
        cl.cns[i % 3].get_batch(keys[i * 128:(i + 1) * 128])
    assert 3 in cl.live
    h = [e for e in cl.handoffs if e.reason == "join"]
    assert len(h) == 1 and h[0].cn == 3 and len(h[0].moved) > 0
    # O(shards moved): the metered bytes are exactly the moved shards'
    # CN-half sizes (seeds + othello arrays + header) — keys never appear
    expect = sum(cl.cn_half_bytes(s) for s, _o, _n in h[0].moved)
    assert h[0].bytes_moved == expect
    led3 = cl.ledgers[3].snapshot()
    assert led3["resp_bytes"] - led3_before >= expect
    assert led3["fault_wait_us"] > 0  # lease-gated cutover drain
    # every move lands on the joiner or rebalances onto a live CN
    for _s, old, new in h[0].moved:
        assert new in cl.live and new != old
    # the joiner now serves reads correctly
    r = cl.cns[3].get_batch(keys[:256])
    assert r.found.all()


def test_leave_loses_no_acked_writes(data):
    keys, vals, extra, evals = data
    sched = MembershipSchedule.single_leave(at_op=500, cn=1, seed=3)
    cl = cluster_of(_spec(), keys, vals, n_cns=2, membership=sched)
    acked = []
    w = cl.cns[1].update_batch(keys[:256],
                               np.arange(1, 257, dtype=np.uint64))
    acked += [(int(k), int(v)) for k, v, ok in
              zip(keys[:256], np.arange(1, 257), w.found) if ok]
    wi = cl.cns[1].insert_batch(extra[:128], evals[:128])
    acked += [(int(k), int(v)) for k, v, ok in
              zip(extra[:128], evals[:128], wi.found) if ok]
    # drive past the leave point
    for i in range(4):
        cl.cns[0].get_batch(keys[256 + i * 64:256 + (i + 1) * 64])
    assert 1 not in cl.live
    assert any(e.reason == "leave" for e in cl.handoffs)
    # the departed CN answers degraded, never serves
    r_dead = cl.cns[1].get_batch(keys[:8])
    assert not r_dead.found.any()
    assert set(r_dead.statuses) == {"unavailable"}
    # every write CN 1 acked is readable through the survivor
    ak = np.asarray([k for k, _ in acked], dtype=np.uint64)
    av = np.asarray([v for _, v in acked], dtype=np.uint64)
    r = cl.cns[0].get_batch(ak)
    lost = int((~(r.found & (r.values == av))).sum())
    assert lost == 0, f"{lost} acked writes lost through the leave"


def test_cn_crash_degrades_then_rejoins(data):
    keys, vals, _, _ = data
    sched = MembershipSchedule(events=(
        MembershipEvent("cn_crash", at_op=256, cn=1,
                        duration_ops=512, down_s=2e-4),), seed=1)
    cl = cluster_of(_spec(), keys, vals, n_cns=2, membership=sched)
    cl.cns[0].get_batch(keys[:256])     # crosses at_op: CN 1 dies
    assert 1 not in cl.live
    r = cl.cns[1].get_batch(keys[:32])  # dead CN: degraded answers
    assert not r.found.any() and set(r.statuses) == {"unavailable"}
    assert cl.stats.rejected_lanes >= 32
    # the crash is recorded on the dead CN's trace for the replay
    from repro.net.transport import FaultMark
    marks = [m for m in cl.transports[1].trace
             if isinstance(m, FaultMark) and m.kind == "cn_crash"]
    assert len(marks) == 1 and marks[0].down_s == pytest.approx(2e-4)
    # survivors serve throughout; after the window the CN rejoins
    cl.cns[0].get_batch(keys[:512])
    r2 = cl.cns[1].get_batch(keys[:32])
    assert 1 in cl.live and r2.found.all()
    reasons = [e.reason for e in cl.handoffs]
    assert "cn_crash" in reasons and "cn_restart" in reasons


def test_ownership_rebalance_is_minimal_and_deterministic():
    t1 = OwnershipTable(64, live=(0, 1, 2), seed=11)
    t2 = OwnershipTable(64, live=(0, 1, 2), seed=11)
    assert t1.owners == t2.owners
    before = list(t1.owners)
    moved = t1.rebalance((0, 1, 2, 3))
    # minimality: every move lands on the joiner; survivors keep the rest
    assert all(new == 3 for _s, _o, new in moved)
    for s in range(64):
        if before[s] != t1.owners[s]:
            assert t1.owners[s] == 3
    # removing the joiner restores the original placement exactly
    t1.rebalance((0, 1, 2))
    assert t1.owners == before


def test_shard_epochs_semantics():
    ep = ShardEpochs(4, n_cns=2)
    ep.bump(0, np.asarray([1, 2]))
    assert list(ep.stale_shards(1, np.asarray([0, 1, 2, 3]))) == [1, 2]
    assert ep.stale_shards(0, np.asarray([1, 2])).size == 0  # writer current
    ep.sync(1, np.asarray([1, 2]))
    assert ep.stale_shards(1, np.asarray([1, 2])).size == 0
    ep.grow(6)  # split: new shards start current everywhere
    assert ep.n_shards == 6
    assert ep.stale_shards(1, np.asarray([4, 5])).size == 0


# ------------------------------------------------------------ specs / JSON

def test_membership_schedule_json_roundtrip():
    sched = MembershipSchedule(
        events=(MembershipEvent("join", 100, 2),
                MembershipEvent("cn_crash", 200, 0, duration_ops=50,
                                down_s=1e-4),
                MembershipEvent("leave", 400, 1)),
        seed=9, initial=(0, 1))
    back = MembershipSchedule.from_json(sched.to_json())
    assert back == sched
    gen = MembershipSchedule.generate(5, 4096, n_cns=4)
    assert MembershipSchedule.from_json(gen.to_json()) == gen


def test_cluster_spec_validation_and_roundtrip():
    spec = ClusterSpec(store=_spec(), n_cns=4, n_mns=2,
                       membership=MembershipSchedule.single_join(64, 3))
    spec.validate()
    assert ClusterSpec.from_json(spec.to_json()) == spec
    with pytest.raises(SpecError):
        ClusterSpec(store=StoreSpec(kind="outback"), n_cns=2).validate()
    with pytest.raises(SpecError):
        ClusterSpec(store=_spec(), n_cns=0).validate()
    with pytest.raises(SpecError):  # membership names a CN out of range
        ClusterSpec(store=_spec(), n_cns=2,
                    membership=MembershipSchedule.single_join(10, 5)
                    ).validate()
    with pytest.raises(SpecError):  # MN pool striping vs replication
        ClusterSpec(store=StoreSpec(kind="outback-dir", replicas=2),
                    n_mns=2).validate()


def test_fault_schedule_cn_crash_validation(data):
    keys, vals, _, _ = data
    with pytest.raises(ValueError):  # cn_crash is CN-side: no mn target
        FaultEvent("cn_crash", 10, 20, mn=1, cn=0, down_s=1e-4).validate()
    with pytest.raises(ValueError):  # needs a sim-plane outage
        FaultEvent("cn_crash", 10, 20, cn=0).validate()
    # rides a StoreSpec without tripping the replica-bound check...
    sched = FaultSchedule(events=(
        FaultEvent("cn_crash", 64, 128, cn=1, down_s=1e-4),),
        lease_term_ops=32)
    StoreSpec(kind="outback-dir", faults=sched).validate()
    # ...and the cluster lifts it into a membership window
    lifted = MembershipSchedule.from_faults(sched)
    assert lifted.events[0].kind == "cn_crash"
    assert lifted.events[0].duration_ops == 128
    cl = cluster_of(StoreSpec(kind="outback-dir", faults=sched,
                              cache_budget_bytes=16 << 10),
                    keys, vals, n_cns=2)
    cl.cns[0].get_batch(keys[:128])  # crosses at_op 64: CN 1 crashes
    assert 1 not in cl.live


# --------------------------------------- write-combining reconciliation

def _wc_run(data, combine):
    keys, vals, extra, _ = data
    spec = _spec(batch=BatchPolicy(window=512, combine_reads=combine))
    st = open_store(spec, keys, vals)
    answers = []
    # failing updates (absent keys) + combined/hazard reads of them
    st.submit("update", extra[:16], np.arange(1, 17, dtype=np.uint64))
    h1 = st.submit("get", extra[:16])
    # succeeding updates + reads (the combine fast path, no fixup needed)
    st.submit("update", keys[:16], np.arange(101, 117, dtype=np.uint64))
    h2 = st.submit("get", keys[:16])
    # delete of an absent key + read
    st.submit("delete", extra[16:20])
    h3 = st.submit("get", extra[16:20])
    st.flush()
    for h in (h1, h2, h3):
        r = h.result()
        answers.append(([int(v) for v in r.values],
                        [bool(f) for f in r.found]))
    return answers, st.stats


def test_combined_reads_reconcile_to_uncombined_answers(data):
    a_on, s_on = _wc_run(data, combine=True)
    a_off, s_off = _wc_run(data, combine=False)
    assert a_on == a_off
    assert s_on.combined_reads > 0 and s_on.reconciled_reads > 0
    assert s_off.combined_reads == 0 and s_off.reconciled_reads == 0
    # hazard flushes disappear when combining serves the reads locally
    assert s_on.hazard_flushes < s_off.hazard_flushes


# ----------------------------------------------------------------- replay

def test_simulate_cluster_single_cn_matches_simulate(data):
    keys, vals, _, _ = data
    cl = cluster_of(_spec(), keys, vals, n_cns=1)
    cl.cns[0].get_batch(keys[:512])
    cl.cns[0].update_batch(keys[:64], np.arange(1, 65, dtype=np.uint64))
    trace = cl.transports[0].trace
    r1 = simulate(trace, clients=4, window=8)
    r2 = simulate_cluster([trace], clients_per_cn=4, window=8)
    assert r1.n_ops == r2.n_ops
    assert r1.seconds == pytest.approx(r2.seconds, rel=0, abs=0)
    assert np.array_equal(r1.latencies_us, r2.latencies_us)


def test_simulate_cluster_is_deterministic_and_scales(data):
    keys, vals, _, _ = data
    cl = cluster_of(_spec(params={"initial_depth": 2}), keys, vals,
                    n_cns=4, n_mns=2)
    rng = np.random.default_rng(2)
    for step in range(12):
        idx = rng.integers(0, N, size=256)
        cl.cns[step % 4].get_batch(keys[idx])
    traces = [t.trace for t in cl.transports]
    r1 = simulate_cluster(traces, clients_per_cn=2, window=8, replicas=2)
    r2 = simulate_cluster(traces, clients_per_cn=2, window=8, replicas=2)
    assert r1.n_ops == r2.n_ops and r1.seconds == r2.seconds
    assert np.array_equal(r1.latencies_us, r2.latencies_us)
    # 4 CNs replaying in parallel beat one CN consuming the same ops
    merged = [it for t in traces for it in t]
    solo = simulate(merged, clients=2, window=8, replicas=2)
    assert r1.seconds < solo.seconds


def test_cluster_cn_crash_mark_records_availability_window(data):
    keys, vals, _, _ = data
    sched = MembershipSchedule(events=(
        MembershipEvent("cn_crash", 128, 1, duration_ops=256,
                        down_s=3e-4),), seed=0)
    cl = cluster_of(_spec(), keys, vals, n_cns=2, membership=sched)
    for i in range(6):
        cl.cns[i % 2].get_batch(keys[i * 64:(i + 1) * 64])
    res = simulate_cluster([t.trace for t in cl.transports],
                           clients_per_cn=2, window=4)
    kinds = {k for _a, _b, k, _r in res.fault_windows}
    assert "cn_crash" in kinds
    cn_win = [w for w in res.fault_windows if w[2] == "cn_crash"]
    assert cn_win[0][1] - cn_win[0][0] == pytest.approx(3e-4)
    # availability dict schema carries the window for the CI validator
    avail = res.availability()
    assert avail["schema"] == "outback-availability/v1"
    assert any(w[2] == "cn_crash" for w in avail["fault_windows"])
