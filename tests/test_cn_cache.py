"""CN-side hot-key cache: admission, budget, coherence, probe equivalence."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cn_cache import (CNKeyCache, ShardedCNCache, cache_probe,
                                 neg_probe)
from repro.core.hashing import split_u64, splitmix64
from repro.core.outback import OutbackShard
from repro.core.store import OutbackStore, make_uniform_keys

N = 20_000
BUDGET = 8 * N


@pytest.fixture(scope="module")
def kv():
    keys = make_uniform_keys(N)
    return keys, splitmix64(keys)


def _shard(kv, budget=BUDGET):
    keys, vals = kv
    cache = CNKeyCache(budget)
    return OutbackShard(keys, vals, load_factor=0.85, cn_cache=cache), cache


def _val(k):
    return int(splitmix64(np.uint64([k]))[0])


# ------------------------------------------------------------------ budget
def test_budget_respected():
    for budget in (4 << 10, 64 << 10, 1 << 20):
        c = CNKeyCache(budget)
        assert c.memory_bytes() <= budget
        assert c.capacity >= 8


def test_budget_too_small_rejected():
    with pytest.raises(ValueError):
        CNKeyCache(100)


# --------------------------------------------------------------- admission
def test_hot_key_admitted_after_reuse(kv):
    sh, cache = _shard(kv)
    k = int(kv[0][0])
    r1 = sh.get(k)  # miss, freq=1: below the admission threshold
    r2 = sh.get(k)  # miss, freq=2: admitted on fill
    r3 = sh.get(k)  # hit
    assert r1.value == r2.value == r3.value == _val(k)
    assert r3.round_trips == 0
    assert cache.stats.hits == 1 and cache.stats.admitted == 1
    assert sh.meter.saved_round_trips == 1


def test_one_shot_scan_not_admitted(kv):
    """A cold scan (every key once) must not pollute the cache (a handful
    of count-min collisions may sneak past the threshold)."""
    sh, cache = _shard(kv)
    for k in kv[0][:500]:
        sh.get(int(k))
    assert cache.stats.admitted <= 3


def test_cold_burst_cannot_flush_hot_set(kv):
    sh, cache = _shard(kv, budget=64 << 10)
    hot = kv[0][:16]
    for _ in range(6):  # make them definitively hot
        for k in hot:
            sh.get(int(k))
    hot_cached = int(cache.valid.sum())
    assert hot_cached >= 14
    for k in kv[0][1000:3000]:  # one-touch cold burst
        sh.get(int(k))
    # hot keys still answer locally: the frequency gate protected them
    before = cache.stats.hits
    for k in hot:
        sh.get(int(k))
    assert cache.stats.hits - before >= hot_cached - 2  # CLOCK may rotate 1-2


# ---------------------------------------------------------- negative cache
def test_negative_cache_absorbs_repeated_misses(kv):
    sh, cache = _shard(kv)
    absent = 0xDEAD_BEEF_0001
    assert sh.get(absent).value is None  # freq 1
    assert sh.get(absent).value is None  # freq 2 -> neg-admitted
    r = sh.get(absent)
    assert r.value is None and r.round_trips == 0
    assert cache.stats.neg_hits >= 1
    # Insert clears the negative entry (coherence)
    sh.insert(absent, 777)
    assert sh.get(absent).value == 777


# ---------------------------------------------------------------- coherence
def test_update_refreshes_cached_value(kv):
    sh, cache = _shard(kv)
    k = int(kv[0][1])
    for _ in range(3):
        sh.get(k)  # cached now
    assert sh.update(k, 4242)
    assert sh.get(k).value == 4242  # served from cache, must be fresh
    assert cache.stats.hits >= 2


def test_delete_invalidates_cached_value(kv):
    sh, cache = _shard(kv)
    k = int(kv[0][2])
    for _ in range(3):
        sh.get(k)
    assert sh.delete(k)
    assert cache.stats.invalidated >= 1
    assert sh.get(k).value is None


def test_cache_equivalent_to_uncached_mixed_workload(kv):
    keys, vals = kv
    sh_c, _ = _shard(kv)
    sh_u = OutbackShard(keys, vals, load_factor=0.85)
    rng = np.random.default_rng(7)
    for i in range(2000):
        k = int(keys[rng.integers(0, 2000)])
        op = rng.integers(0, 10)
        if op < 6:
            assert sh_c.get(k).value == sh_u.get(k).value
        elif op < 8:
            v = int(rng.integers(0, 2**63))
            assert sh_c.update(k, v) == sh_u.update(k, v)
        elif op == 8:
            assert sh_c.delete(k) == sh_u.delete(k)
        else:
            v = int(rng.integers(0, 2**63))
            assert sh_c.insert(k, v) == sh_u.insert(k, v)


# -------------------------------------------------------------- batch path
def test_get_batch_with_cache_matches_values(kv):
    keys, vals = kv
    sh, cache = _shard(kv)
    rng = np.random.default_rng(3)
    idx = rng.zipf(1.5, 4096) % 3000
    q = keys[idx]
    for _ in range(3):
        v_lo, v_hi, match = sh.get_batch(q)
    assert np.asarray(match).all()
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | \
        np.asarray(v_lo).astype(np.uint64)
    np.testing.assert_array_equal(got, splitmix64(q))
    assert cache.stats.hits > 0
    assert sh.meter.saved_round_trips == sh.meter.cache_hits \
        + 2 * sh.meter.cache_neg_hits


def test_cache_off_meter_unchanged(kv):
    """cn_cache=None keeps the accounting byte-for-byte as before."""
    keys, vals = kv
    sh = OutbackShard(keys, vals, load_factor=0.85)
    sh.meter.reset()
    sh.get_batch(keys[:1024])
    m = sh.meter
    assert (m.ops, m.round_trips) == (1024, 1024)
    # both directions of an RPC message are padded to MSG_BYTES (§5.1)
    assert m.req_bytes == 1024 * 64 and m.resp_bytes == 1024 * 64
    assert m.cache_hits == m.saved_round_trips == m.saved_req_bytes == 0


def test_get_batch_resolves_overflow_residents(kv):
    """resolve_makeup serves keys living in the MN overflow cache."""
    keys, vals = kv
    sh, _ = _shard(kv)
    extra = splitmix64(np.arange(1, 400, dtype=np.uint64) + np.uint64(1 << 40))
    for k in extra:
        sh.insert(int(k), _val(int(k)) & (2**63 - 1))
    v_lo, v_hi, match = sh.get_batch(extra)
    assert np.asarray(match).all()


# --------------------------------------------------- pure probe (np == jnp)
def test_cache_probe_np_jnp_agree(kv):
    sh, cache = _shard(kv)
    for k in kv[0][:64]:
        sh.get(int(k))
        sh.get(int(k))
    q = np.concatenate([kv[0][:64], kv[0][5000:5064]])
    lo, hi = split_u64(q)
    hit_n, vlo_n, vhi_n = cache_probe(lo, hi, cache.arrays(), cache.nsets)
    hit_j, vlo_j, vhi_j = cache_probe(jnp.asarray(lo), jnp.asarray(hi),
                                      cache.arrays(jnp), cache.nsets, jnp)
    np.testing.assert_array_equal(hit_n, np.asarray(hit_j))
    np.testing.assert_array_equal(vlo_n, np.asarray(vlo_j))
    np.testing.assert_array_equal(vhi_n, np.asarray(vhi_j))
    assert hit_n[:64].sum() > 0 and not hit_n[64:].any()

    neg_n = neg_probe(lo, hi, cache.neg_arrays(), cache.nneg)
    neg_j = neg_probe(jnp.asarray(lo), jnp.asarray(hi),
                      cache.neg_arrays(jnp), cache.nneg, jnp)
    np.testing.assert_array_equal(neg_n, np.asarray(neg_j))


# ------------------------------------------------------------ store + resize
def test_store_cache_survives_mutations(kv):
    keys, vals = kv
    store = OutbackStore(keys, vals, load_factor=0.85,
                         cn_cache_budget_bytes=BUDGET)
    k = int(keys[0])
    for _ in range(3):
        assert store.get(k).value == _val(k)
    assert store.cn_cache.stats.hits >= 1
    store.update(k, 99)
    assert store.get(k).value == 99
    store.delete(k)
    assert store.get(k).value is None


def test_store_split_invalidates_routed_entries():
    keys = make_uniform_keys(3000, seed=11)
    vals = splitmix64(keys)
    store = OutbackStore(keys, vals, load_factor=0.85,
                         cn_cache_budget_bytes=64 << 10)
    hot = keys[:200]
    for _ in range(3):
        for k in hot:
            store.get(int(k))
    assert int(store.cn_cache.valid.sum()) > 0
    inv_before = store.cn_cache.stats.invalidated
    # force a split of table 0 and check the invalidation hook ran
    store._split(0)
    assert store.cn_cache.stats.invalidated > inv_before
    assert len(store.tables) == 2
    # correctness after the swap: every key still readable, fresh admissions OK
    for k in hot:
        assert store.get(int(k)).value == _val(int(k))


def test_sharded_cn_cache_replicas():
    c = CNKeyCache(16 << 10)
    sc = ShardedCNCache(c, 4)
    arrs = sc.arrays()
    assert all(a.shape[0] == 4 for a in arrs)
    assert sc.memory_bytes_total() == 4 * c.memory_bytes()


@pytest.mark.mesh
def test_sharded_get_with_cache_single_device():
    """SPMD Get with the probe stage: hits skip the bins, results exact."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import sharded_kvs as skv

    n, batch = 20_000, 2048
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    keys = make_uniform_keys(n)
    vals = splitmix64(keys)
    st = skv.build_sharded(keys, vals, num_shards=1, data_parallel=1,
                          load_factor=0.85)
    arrays = skv.place_state(mesh, st)

    host = CNKeyCache(8 * n)
    rng = np.random.default_rng(3)
    q = keys[rng.zipf(1.6, batch) % n]
    lo, hi = split_u64(q)
    host._sketch_bump(lo, hi)
    host._sketch_bump(lo, hi)
    for k in q[:500]:
        host.fill(int(k), _val(int(k)))
    scache = ShardedCNCache(host, 1)
    cache_arrays = skv.place_cache(mesh, scache)
    fn, _ = skv.make_get_fn(mesh, st, batch, cache=scache)
    qs = NamedSharding(mesh, P(("data", "model")))
    qlo = jax.device_put(jnp.asarray(lo), qs)
    qhi = jax.device_put(jnp.asarray(hi), qs)
    v_lo, v_hi, match, hit = fn(qlo, qhi, *cache_arrays, *arrays)
    assert np.asarray(match).all()
    assert np.asarray(hit).sum() > 0
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | \
        np.asarray(v_lo).astype(np.uint64)
    np.testing.assert_array_equal(got, splitmix64(q))


# ------------------------------------------------------------ session store
def test_session_store_roundtrip_reads_through_cache():
    from repro.serve import KVSessionStore
    ss = KVSessionStore(cn_cache_budget_bytes=64 << 10)
    blob = np.random.default_rng(0).bytes(4093)
    ss.put(7, blob)
    assert ss.get(7) == blob
    h0 = ss.cache_stats.hits
    assert ss.get(7) == blob  # second read: CN cache
    assert ss.cache_stats.hits > h0
    assert ss.get(999) is None
    assert ss.delete(7) and not ss.delete(7)
    assert ss.get(7) is None
