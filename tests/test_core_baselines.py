"""Baseline KVSs: correctness + the comparative claims the paper relies on."""

import numpy as np
import pytest

from repro.core.baselines import ClusterKVS, DummyKVS, MicaKVS, RaceKVS
from repro.core.hashing import splitmix64
from repro.core.outback import OutbackShard
from repro.core.store import make_uniform_keys

N = 30_000


@pytest.fixture(scope="module")
def data():
    keys = make_uniform_keys(N, 7)
    return keys, splitmix64(keys)


@pytest.mark.parametrize("cls", [RaceKVS, MicaKVS, ClusterKVS])
def test_baseline_get_correct(cls, data):
    keys, vals = data
    kvs = cls(keys, vals)
    for i in range(0, N, 997):
        assert kvs.get(int(keys[i])) == int(vals[i])
    assert kvs.get(2**63 + 12345) is None


@pytest.mark.parametrize("cls", [RaceKVS, MicaKVS, ClusterKVS, DummyKVS])
def test_baseline_get_batch(cls, data):
    keys, vals = data
    kvs = cls(keys, vals)
    v_lo, v_hi, match = kvs.get_batch(keys[:4096])
    if cls is DummyKVS:
        return  # dummy returns arbitrary blocks by design
    m = np.asarray(match)
    assert m.mean() > 0.999
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(v_lo)
    np.testing.assert_array_equal(got[m], vals[:4096][m])


def test_round_trip_ordering(data):
    """Outback: 1 RT.  RPC baselines: 1 RT.  RACE (one-sided): 2 RTs."""
    keys, vals = data
    out = OutbackShard(keys, vals, load_factor=0.85)
    race, mica = RaceKVS(keys, vals), MicaKVS(keys, vals)
    for kvs in (out, race, mica):
        kvs.meter.reset()
        kvs.get_batch(keys[:1024])
    po = out.meter.per_op()
    pr = race.meter.per_op()
    pm = mica.meter.per_op()
    assert po["round_trips"] == 1 and pm["round_trips"] == 1
    assert pr["round_trips"] == 2


def test_mn_compute_ordering(data):
    """The paper's central claim: Outback's MN does no index compute while
    RPC baselines burn MN cycles on probing/compares."""
    keys, vals = data
    out = OutbackShard(keys, vals, load_factor=0.85)
    mica, clus = MicaKVS(keys, vals), ClusterKVS(keys, vals)
    for kvs in (out, mica, clus):
        kvs.meter.reset()
        kvs.get_batch(keys[:1024])
    assert out.meter.mn_cmp_ops == 0 and out.meter.mn_hash_ops == 0
    assert mica.meter.mn_cmp_ops > 0
    assert clus.meter.mn_cmp_ops > 0


def test_onwire_bytes_ordering(data):
    """RACE moves bucket groups over the wire; Outback moves 8-byte indices."""
    keys, vals = data
    out = OutbackShard(keys, vals, load_factor=0.85)
    race = RaceKVS(keys, vals)
    out.meter.reset(), race.meter.reset()
    out.get_batch(keys[:1024])
    race.get_batch(keys[:1024])
    assert race.meter.resp_bytes > out.meter.resp_bytes
