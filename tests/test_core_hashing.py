"""Primitives: hashing, bit arrays, slot bitfields — np/jnp equivalence."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import bitarray, slots
from repro.core.hashing import (fingerprint6, fingerprint6_int, fmix32,
                                fmix32_int, hash64_32, hash64_32_int,
                                hash_range, hash_range_int, join_u64,
                                popcount32, slot_hash, slot_hash_int,
                                split_u64, splitmix64)

u32s = st.integers(min_value=0, max_value=2**32 - 1)
u64s = st.integers(min_value=0, max_value=2**64 - 1)


@settings(deadline=None, max_examples=50)
@given(st.lists(u32s, min_size=1, max_size=64), u32s)
def test_fmix32_np_jnp_agree(vals, seed):
    a = np.asarray(vals, dtype=np.uint32)
    np_out = fmix32(a ^ np.uint32(seed), np)
    j_out = fmix32(jnp.asarray(a) ^ jnp.uint32(seed), jnp)
    np.testing.assert_array_equal(np_out, np.asarray(j_out))


@settings(deadline=None, max_examples=50)
@given(st.lists(u64s, min_size=1, max_size=64), u32s)
def test_hash64_np_jnp_agree(keys, seed):
    lo, hi = split_u64(np.asarray(keys, dtype=np.uint64))
    np_out = hash64_32(lo, hi, seed, np)
    j_out = hash64_32(jnp.asarray(lo), jnp.asarray(hi), jnp.uint32(seed), jnp)
    np.testing.assert_array_equal(np_out, np.asarray(j_out))


@settings(deadline=None, max_examples=30)
@given(st.lists(u64s, min_size=1, max_size=64),
       st.integers(min_value=1, max_value=10_000))
def test_hash_range_in_bounds(keys, size):
    lo, hi = split_u64(np.asarray(keys, dtype=np.uint64))
    h = hash_range(lo, hi, 7, size)
    assert (h < size).all()


@settings(deadline=None, max_examples=30)
@given(st.lists(u64s, min_size=1, max_size=64), st.integers(0, 255))
def test_slot_hash_range_and_agreement(keys, seed):
    lo, hi = split_u64(np.asarray(keys, dtype=np.uint64))
    s_np = slot_hash(lo, hi, np.uint32(seed))
    s_j = slot_hash(jnp.asarray(lo), jnp.asarray(hi), jnp.uint32(seed), jnp)
    assert (s_np < 4).all()
    np.testing.assert_array_equal(s_np, np.asarray(s_j))


@settings(deadline=None, max_examples=30)
@given(st.lists(u64s, min_size=1, max_size=32, unique=True))
def test_split_join_roundtrip(keys):
    k = np.asarray(keys, dtype=np.uint64)
    lo, hi = split_u64(k)
    np.testing.assert_array_equal(join_u64(lo, hi), k)


def test_fingerprint_is_6bit():
    lo, hi = split_u64(splitmix64(np.arange(1, 10_001, dtype=np.uint64)))
    fp = fingerprint6(lo, hi)
    assert (fp < 64).all()
    # fingerprints should be reasonably uniform
    counts = np.bincount(fp, minlength=64)
    assert counts.min() > 0


@settings(deadline=None, max_examples=30)
@given(st.lists(st.integers(0, 4095), min_size=1, max_size=256),
       st.integers(min_value=4096, max_value=8192))
def test_bitarray_set_get(bits_on, m):
    words = bitarray.alloc_bits(m)
    for b in bits_on:
        bitarray.set_bit(words, b, 1)
    idx = np.arange(4096)
    got = bitarray.get_bit(words, idx)
    expect = np.zeros(4096, dtype=np.uint32)
    expect[np.asarray(sorted(set(bits_on)))] = 1
    np.testing.assert_array_equal(got, expect)
    # jnp path agrees
    got_j = bitarray.get_bit(jnp.asarray(words), jnp.asarray(idx), jnp)
    np.testing.assert_array_equal(np.asarray(got_j), expect)


@settings(deadline=None, max_examples=60)
@given(u32s, u32s, u32s, st.integers(1, 100_000))
def test_int_twins_bit_identical(lo, hi, seed, size):
    """The pure-int scalar hashes (the fast path of the scalar protocol
    walks) must equal the array versions bit-for-bit."""
    l32, h32, s32 = np.uint32(lo), np.uint32(hi), np.uint32(seed)
    assert fmix32_int(lo) == int(fmix32(l32))
    assert hash64_32_int(lo, hi, seed) == int(hash64_32(l32, h32, s32))
    assert hash_range_int(lo, hi, seed, size) == int(
        hash_range(l32, h32, s32, size))
    assert slot_hash_int(lo, hi, seed & 0xFF) == int(
        slot_hash(l32, h32, np.uint32(seed & 0xFF)))
    assert fingerprint6_int(lo, hi) == int(fingerprint6(l32, h32))


@settings(deadline=None, max_examples=40)
@given(st.lists(u32s, min_size=1, max_size=64))
def test_popcount32_np_jnp_agree(vals):
    a = np.asarray(vals, dtype=np.uint32)
    expect = np.asarray([bin(v).count("1") for v in vals], np.uint32)
    np.testing.assert_array_equal(popcount32(a), expect)
    np.testing.assert_array_equal(np.asarray(popcount32(jnp.asarray(a), jnp)),
                                  expect)


@settings(deadline=None, max_examples=50)
@given(st.integers(0, 1), st.integers(0, 63), st.integers(0, 511),
       u32s, st.integers(0, 0xFFFF))
def test_slot_pack_unpack_roundtrip(cache, fp, length, alo, ahi):
    lo, hi = slots.pack(cache, fp, length, alo, ahi)
    f = slots.unpack(lo, hi)
    assert int(f["cache"]) == cache
    assert int(f["fp"]) == fp
    assert int(f["len"]) == length
    assert int(f["addr_lo"]) == alo
    assert int(f["addr_hi"]) == ahi
    assert int(slots.unpack_len(hi)) == length
