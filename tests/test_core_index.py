"""Othello / Ludo / OutbackShard / OutbackStore behaviour + invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ludo, othello
from repro.core.hashing import split_u64, splitmix64, slot_hash
from repro.core.outback import OutbackShard
from repro.core.overflow import OverflowCache
from repro.core.store import OutbackStore, make_uniform_keys


def _keys(n, seed=1):
    return make_uniform_keys(n, seed)


# ---------------------------------------------------------------- Othello
@settings(deadline=None, max_examples=12)
@given(st.integers(min_value=1, max_value=3000), st.integers(0, 5))
def test_othello_exact_on_members(n, seed):
    keys = _keys(n, seed + 2)
    lo, hi = split_u64(keys)
    values = (splitmix64(keys) & np.uint64(1)).astype(np.uint8)
    oth = othello.build(lo, hi, values, seed=seed)
    np.testing.assert_array_equal(oth.lookup(lo, hi), values.astype(np.uint32))
    # jnp lookup path agrees
    got = oth.lookup(jnp.asarray(lo), jnp.asarray(hi), jnp,
                     words_a=jnp.asarray(oth.words_a),
                     words_b=jnp.asarray(oth.words_b))
    np.testing.assert_array_equal(np.asarray(got), values.astype(np.uint32))


def test_othello_memory_budget():
    keys = _keys(100_000)
    lo, hi = split_u64(keys)
    oth = othello.build(lo, hi, np.zeros(keys.size, np.uint8))
    assert oth.bits / keys.size < 2.5  # paper: 2.33 bits/key


# ------------------------------------------------------------------- Ludo
@settings(deadline=None, max_examples=8)
@given(st.integers(min_value=8, max_value=4000),
       st.sampled_from([0.5, 0.75, 0.9, 0.95]))
def test_ludo_perfect_hashing(n, lf):
    keys = _keys(n, 3)
    lo, hi = split_u64(keys)
    b = ludo.build(lo, hi, load_factor=lf)
    assert b.ok
    # perfect: (bucket, slot) unique over all keys
    pos = b.bucket.astype(np.int64) * 4 + b.slot
    assert np.unique(pos).size == n
    # locate() agrees with the build assignment
    bb, ss = b.cn.locate(lo, hi)
    np.testing.assert_array_equal(bb, b.bucket)
    np.testing.assert_array_equal(ss, b.slot)
    # occupancy <= 4 everywhere
    counts = np.bincount(b.bucket, minlength=b.cn.num_buckets)
    assert counts.max() <= 4


def test_ludo_seed_search_contract():
    keys = _keys(64, 9)
    lo, hi = split_u64(keys)
    s = ludo.find_bucket_seed(lo[:4], hi[:4])
    assert s is not None and 0 <= s < 256
    assert np.unique(slot_hash(lo[:4], hi[:4], np.uint32(s))).size == 4


def test_ludo_memory_matches_paper_formula():
    # paper §4.5: CN memory = (2.33 + 2/eps) n bits
    n, eps = 200_000, 0.95
    keys = _keys(n)
    lo, hi = split_u64(keys)
    b = ludo.build(lo, hi, load_factor=eps)
    bits = (b.cn.othello.bits + 8 * b.cn.num_buckets) / n
    assert bits == pytest.approx(2.33 + 2 / eps, rel=0.05)


# ----------------------------------------------------------- OverflowCache
@settings(deadline=None, max_examples=20)
@given(st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1),
                          st.integers(0, 2**31 - 1)),
                min_size=1, max_size=120, unique_by=lambda t: (t[0], t[1])))
def test_overflow_cache_model(entries):
    cache = OverflowCache(256)
    model = {}
    for lo, hi, addr in entries:
        ok, _ = cache.insert(lo, hi, addr)
        if ok:
            model[(lo, hi)] = addr
    for (lo, hi), addr in model.items():
        got, _ = cache.lookup(lo, hi)
        assert got == addr
    # delete half, verify the rest still resolves (backward-shift correctness)
    dels = list(model)[::2]
    for lo, hi in dels:
        assert cache.delete(lo, hi)[0]
        del model[(lo, hi)]
    for (lo, hi), addr in model.items():
        got, _ = cache.lookup(lo, hi)
        assert got == addr
    for lo, hi in dels:
        assert cache.lookup(lo, hi)[0] is None


# ------------------------------------------------------------ OutbackShard
@pytest.fixture(scope="module")
def shard():
    keys = _keys(50_000)
    vals = splitmix64(keys)
    return OutbackShard(keys, vals, load_factor=0.85), keys, vals


def test_shard_get_one_round_trip(shard):
    sh, keys, vals = shard
    sh.meter.reset()
    r = sh.get(int(keys[7]))
    assert r.value == int(vals[7])
    assert r.round_trips == 1 and not r.makeup
    # MN did zero hash/compare work on the fast path
    assert sh.meter.mn_hash_ops == 0 and sh.meter.mn_cmp_ops == 0
    assert sh.meter.mn_mem_reads == 2  # slot word + heap block


def test_shard_get_batch_matches_single(shard):
    sh, keys, vals = shard
    q = keys[:4096]
    v_lo, v_hi, match = sh.get_batch(q)
    assert match.all()
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(v_lo)
    np.testing.assert_array_equal(got, vals[:4096])


def test_shard_get_batch_jnp(shard):
    sh, keys, vals = shard
    v_lo, v_hi, match = sh.get_batch(keys[:512], xp=jnp)
    assert np.asarray(match).all()


def test_shard_miss_and_mutations():
    keys = _keys(20_000, 5)
    vals = splitmix64(keys)
    sh = OutbackShard(keys, vals, load_factor=0.80)
    assert sh.get(999_999_999_999).value is None
    # insert new keys; all three protocol cases appear at this fill level.
    # Stop at s_stop like the real protocol would (resize owns the rest).
    new = splitmix64(np.arange(10**6, 10**6 + 3000, dtype=np.uint64))
    inserted, cases = [], set()
    for k in new:
        if sh.must_stop():
            break
        cases.add(sh.insert(int(k), int(k) >> 3))
        inserted.append(k)
    assert cases <= {"slot", "reseed", "overflow", "update"}
    assert len(inserted) > 500
    new = np.asarray(inserted, dtype=np.uint64)
    for k in new:
        assert sh.get(int(k)).value == int(k) >> 3
    # update + delete
    assert sh.update(int(new[0]), 42)
    assert sh.get(int(new[0])).value == 42
    assert sh.delete(int(new[0]))
    assert sh.get(int(new[0])).value is None
    # delete of a never-inserted key is a miss
    assert not sh.delete(123)


def test_shard_reinsert_overflow_resident_is_update():
    """Inserting a key that spilled to the overflow cache must resolve to
    Update: no n_keys drift, no duplicate that resurrects after Delete."""
    keys = _keys(2000, 3)
    sh = OutbackShard(keys, splitmix64(keys), load_factor=0.90)
    extra = splitmix64(np.arange(1, 80, dtype=np.uint64) + np.uint64(9 << 40))
    first = [sh.insert(int(k), 1) for k in extra]
    assert "overflow" in first  # the scenario actually occurred
    n1 = sh.n_keys
    assert all(sh.insert(int(k), 2) == "update" for k in extra)
    assert sh.n_keys == n1
    for k in extra:
        assert sh.get(int(k)).value == 2
        assert sh.delete(int(k))
        assert sh.get(int(k)).value is None  # no resurrection


def test_shard_reseed_keeps_bucket_perfect():
    keys = _keys(8_000, 11)
    vals = splitmix64(keys)
    sh = OutbackShard(keys, vals, load_factor=0.70)
    new = splitmix64(np.arange(5 * 10**6, 5 * 10**6 + 2500, dtype=np.uint64))
    reseeds, done = 0, []
    for k in new:
        if sh.must_stop():
            break
        if sh.insert(int(k), 1) == "reseed":
            reseeds += 1
        done.append(k)
    assert reseeds > 0  # the case actually exercised
    # every original + new key still resolves
    for k in list(keys[:500]) + done[:500]:
        assert sh.get(int(k)).value is not None


def test_cn_memory_is_small(shard):
    sh, keys, _ = shard
    bits_per_key = sh.cn_memory_bytes() * 8 / keys.size
    assert bits_per_key < 6.0  # paper §5.8: ~5 bits/key
    assert sh.mn_index_bytes() > sh.cn_memory_bytes()  # decoupling is real


# ------------------------------------------------------------ OutbackStore
@pytest.mark.slow
def test_store_resize_end_to_end():
    keys = _keys(30_000, 21)
    vals = splitmix64(keys)
    store = OutbackStore(keys, vals, load_factor=0.85, num_compute_nodes=2)
    assert store.global_depth == 0
    # push inserts until at least one split happens
    new = splitmix64(np.arange(7 * 10**6, 7 * 10**6 + 12_000, dtype=np.uint64))
    for k in new:
        store.insert(int(k), int(k) & 0xFFFF)
    assert len(store.resize_events) >= 1
    assert store.global_depth >= 1
    ev = store.resize_events[0]
    assert ev.locator_bytes > 0 and ev.rebuild_seconds > 0
    # all keys (old and new) still resolve post-split
    for k in keys[::97]:
        assert store.get(int(k)).value == int(splitmix64(np.uint64([k]))[0])
    for k in new[::37]:
        assert store.get(int(k)).value == int(k) & 0xFFFF
    # batch get across the directory
    v_lo, v_hi, match = store.get_batch(keys[:2000])
    assert match.mean() > 0.99


def test_store_frozen_inserts_are_buffered_and_replayed():
    keys = _keys(20_000, 31)
    vals = splitmix64(keys)
    store = OutbackStore(keys, vals, load_factor=0.85)
    h = store.begin_split(0)
    # while frozen: gets work (stale table), inserts are FALSE'd
    assert store.get(int(keys[0])).value == int(vals[0])
    assert store.insert(999, 1) == "frozen"
    h.build()
    h.finish()
    assert store.get(999).value == 1  # replayed after the swap
