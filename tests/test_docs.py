"""Docs integrity (ISSUE 6 satellite): every local link in README.md and
docs/*.md resolves, and the documents the README promises exist.

Runs in CI's ``faults-smoke`` lane alongside the crash-recovery bench, so
a PR cannot move or delete a doc without updating its references.
"""

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _local_links(path: Path):
    for target in _LINK.findall(path.read_text()):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
            continue
        yield target


def test_docs_exist():
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()
    assert (ROOT / "docs" / "FAILURE_MODEL.md").is_file()


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_local_links_resolve(doc):
    missing = []
    for target in _local_links(doc):
        resolved = (doc.parent / target).resolve()
        if not resolved.is_relative_to(ROOT):
            continue  # GitHub-side relative URL (e.g. the CI badge)
        if not resolved.exists():
            missing.append(target)
    assert not missing, f"{doc.name} links to missing paths: {missing}"


def test_readme_links_both_architecture_docs():
    text = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in text
    assert "docs/FAILURE_MODEL.md" in text


def test_deprecation_policy_stated_exactly_once():
    """The README states the deprecation policy in ONE place (ISSUE 6):
    one bolded heading owns it; other sections may only reference it."""
    text = (ROOT / "README.md").read_text()
    owners = re.findall(r"\*\*Deprecation policy\*\*", text)
    assert len(owners) == 1, (
        "exactly one '**Deprecation policy**' owner paragraph expected")
