"""Failure/recovery plane (ISSUE 6): deterministic fault injection,
K-way replication with CN-driven failover, leases, and BACKOFF/retry.

The contract under test, in order of importance:

* determinism — same seed + same fault schedule ⇒ identical event trace,
  identical meter snapshots, identical percentiles, identical final MN
  state across two independent runs;
* zero lost acknowledged writes at K=2 through a crash/restart window
  (failover + resync actually happen);
* the no-fault path stays byte-identical when the plane is dormant;
* K=1 degrades to ``"unavailable"`` answers (never blocks, never raises)
  and recovers after the window;
* the replay engine honours replica routing, CN wait stalls and fault
  windows.
"""

import numpy as np
import pytest

from repro.api import (BatchPolicy, ReplicaSetAdapter, SpecError, StoreSpec,
                       open_store)
from repro.net import FaultEvent, FaultPlane, FaultSchedule, Transport
from repro.net.replay import simulate

N = 2048


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(5)
    keys = np.unique(rng.integers(1, 1 << 62, 2 * N + 512, dtype=np.uint64))
    assert len(keys) >= 2 * N
    vals = np.arange(len(keys), dtype=np.uint64)
    return keys[:N], vals[:N], keys[N:2 * N], vals[N:2 * N]


def _crash_spec(**knobs):
    sched = FaultSchedule.single_crash(at_op=64, duration_ops=256,
                                       lease_term_ops=knobs.pop(
                                           "lease_term_ops", 128),
                                       **knobs)
    return StoreSpec("outback", load_factor=0.85, replicas=2, faults=sched)


def _state_sig(x):
    """Canonical, comparable form of an mn_state tree (MN halves only —
    the directory store's shipped CN locators are rebuilt, not compared)."""
    if isinstance(x, dict):
        return tuple(sorted((k, _state_sig(v)) for k, v in x.items()
                            if k != "cn"))
    if isinstance(x, np.ndarray):
        return (x.dtype.str, x.shape, x.tobytes())
    if isinstance(x, (list, tuple)):
        return tuple(_state_sig(v) for v in x)
    return x


# ---------------------------------------------------------------- schedules


def test_schedule_json_roundtrip():
    s = FaultSchedule.generate(7, 4000, replicas=3)
    rt = FaultSchedule.from_json(s.to_json())
    assert rt == s and len(rt.events) > 0


def test_schedule_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("meteor", 1, 2).validate()
    with pytest.raises(ValueError):
        FaultSchedule(events=(FaultEvent("mn_crash", -1, 5),)).validate()
    with pytest.raises(ValueError, match="unknown"):
        FaultEvent.from_json_dict({"kind": "mn_crash", "at_op": 1,
                                   "duration_ops": 2, "spice": 9})


def test_spec_rejects_bad_fault_configs():
    with pytest.raises(SpecError, match="mn_state"):
        StoreSpec("race", replicas=2).validate()
    with pytest.raises(SpecError, match="replicas"):
        StoreSpec("outback", replicas=0).validate()
    with pytest.raises(SpecError, match="targets MN"):
        StoreSpec("outback", replicas=2,
                  faults=FaultSchedule.single_crash(1, 2, mn=3)).validate()


def test_plane_is_deterministic():
    sched = FaultSchedule.generate(21, 3000)
    a, b = FaultPlane(sched), FaultPlane(sched)
    seq_a, seq_b = [], []
    for plane, seq in ((a, seq_a), (b, seq_b)):
        for _ in range(3000):
            plane.tick(1)
            seq.append((plane.crash_open(0), plane.crash_open(1),
                        plane.drop_now(), round(plane.delay_us(), 6),
                        round(plane.backoff_us(2), 6)))
    assert seq_a == seq_b


# ------------------------------------------------------------- determinism


def _run_once(data):
    build_k, build_v, w_k, w_v = data
    tr = Transport()
    st = open_store(_crash_spec(), build_k, build_v, transport=tr)
    for i in range(12):
        st.get_batch(build_k[i * 32:(i + 1) * 32])
        st.insert_batch(w_k[i * 8:(i + 1) * 8], w_v[i * 8:(i + 1) * 8])
    for i in range(12):  # ride past the window so resync happens in-run
        st.get_batch(build_k[i * 32:(i + 1) * 32])
    res = simulate(tr.trace, clients=2, replicas=2)
    return (tr.trace, st.meter_totals().snapshot(), res.percentiles(),
            _state_sig(st.engine.mn_state()))


def test_same_seed_same_schedule_is_bit_identical(data):
    trace_a, snap_a, pct_a, state_a = _run_once(data)
    trace_b, snap_b, pct_b, state_b = _run_once(data)
    assert trace_a == trace_b
    assert snap_a == snap_b
    assert pct_a == pct_b
    assert state_a == state_b


# ------------------------------------------------- crash recovery (K = 2)


def test_zero_lost_acked_writes_at_k2(data):
    build_k, build_v, w_k, w_v = data
    st = open_store(_crash_spec(), build_k, build_v)
    acked = []
    for i in range(24):
        r = st.insert_batch(w_k[i * 8:(i + 1) * 8], w_v[i * 8:(i + 1) * 8])
        stats = r.statuses or ("ok",) * 8
        for k, v, ok, case in zip(w_k[i * 8:], w_v[i * 8:], r.found, stats):
            if ok and case not in ("backoff", "unavailable"):
                acked.append((int(k), int(v)))
        st.get_batch(build_k[:16])
    for _ in range(12):  # let the window close and the resync land
        st.get_batch(build_k[:32])
    m = st.meter_totals()
    assert m.failovers >= 1, "crash never drove a failover"
    assert m.resyncs >= 1, "restart never shipped a state image"
    assert m.retries >= 1 and m.backoffs >= 1
    ak = np.asarray([k for k, _ in acked], np.uint64)
    av = np.asarray([v for _, v in acked], np.uint64)
    g = st.get_batch(ak)
    assert bool(g.found.all()), "acked write unreadable after recovery"
    assert np.array_equal(g.values, av)
    # both replicas converge to the same MN image after resync
    adapter = st
    while not isinstance(adapter, ReplicaSetAdapter):
        adapter = adapter.inner
    sigs = {_state_sig(r.engine.mn_state()) for r in adapter.replicas}
    assert len(sigs) == 1, "replicas diverged after crash recovery"


def test_failover_attribution_lands_on_the_opresult(data):
    build_k, build_v, _, _ = data
    st = open_store(_crash_spec(), build_k, build_v)
    saw = None
    for i in range(40):
        r = st.get_batch(build_k[i * 16:(i + 1) * 16])
        if r.failovers:
            saw = r
            break
    assert saw is not None, "no call carried the failover delta"
    assert saw.retries >= 1 and saw.backoffs >= 1


def test_lease_renewals_follow_the_op_clock(data):
    build_k, build_v, _, _ = data
    spec = StoreSpec("outback", load_factor=0.85, replicas=2,
                     faults=FaultSchedule(lease_term_ops=64))
    st = open_store(spec, build_k, build_v)
    st.get_batch(build_k[:32])
    first = st.meter_totals().lease_renewals
    assert first >= 1  # granted on first use
    for i in range(8):
        st.get_batch(build_k[i * 32:(i + 1) * 32])
    assert st.meter_totals().lease_renewals > first


# --------------------------------------------------------- K = 1 degraded


def test_k1_degrades_to_unavailable_then_recovers(data):
    build_k, build_v, _, _ = data
    sched = FaultSchedule.single_crash(at_op=8, duration_ops=128,
                                       max_retries=1, lease_term_ops=0)
    st = open_store(StoreSpec("outback", load_factor=0.85, faults=sched),
                    build_k, build_v)
    degraded = 0
    for i in range(24):
        r = st.get_batch(build_k[i * 16:(i + 1) * 16])
        if r.statuses is not None:
            degraded += r.statuses.count("unavailable")
            assert not r.found.any()  # degraded lanes answer found=False
    assert degraded > 0
    post = st.get_batch(build_k[:64])
    assert post.statuses is None and bool(post.found.all())


def test_degraded_answers_do_not_poison_the_cn_cache(data):
    build_k, build_v, _, _ = data
    sched = FaultSchedule.single_crash(at_op=4, duration_ops=48,
                                       max_retries=0, lease_term_ops=0)
    st = open_store(StoreSpec("outback", load_factor=0.85, faults=sched,
                              cache_budget_bytes=1 << 15),
                    build_k, build_v)
    for _ in range(8):
        st.get_batch(build_k[:8])
    r = st.get_batch(build_k[:8])
    assert r.statuses is None and bool(r.found.all())
    assert st.meter_totals().cache_neg_hits == 0


# ------------------------------------------------------- dormant identity


def test_dormant_plane_meters_byte_identically(data):
    build_k, build_v, w_k, w_v = data
    snaps, traces = [], []
    for spec in (StoreSpec("outback", load_factor=0.85),
                 StoreSpec("outback", load_factor=0.85,
                           faults=FaultSchedule(lease_term_ops=0))):
        tr = Transport()
        st = open_store(spec, build_k, build_v, transport=tr)
        st.get_batch(build_k[:256])
        st.insert_batch(w_k[:32], w_v[:32])
        st.update_batch(build_k[:32], build_v[:32])
        st.delete_batch(w_k[:16])
        snaps.append(st.meter_totals().snapshot())
        traces.append(tr.trace)
    assert snaps[0] == snaps[1]
    assert traces[0] == traces[1]


# ---------------------------------------------------------------- pipeline


def test_pipelined_handles_resolve_through_a_failover(data):
    build_k, build_v, _, _ = data
    sched = FaultSchedule.single_crash(at_op=70, duration_ops=300,
                                       lease_term_ops=0)
    st = open_store(StoreSpec("outback", load_factor=0.85, replicas=2,
                              faults=sched,
                              batch=BatchPolicy(window=64, order="relaxed")),
                    build_k, build_v)
    handles = [st.submit("get", build_k[i * 32:(i + 1) * 32])
               for i in range(12)]
    st.flush()
    assert all(h.done for h in handles)
    assert sum(int(h.result().found.sum()) for h in handles) == 12 * 32
    assert st.meter_totals().failovers >= 1
    assert st.stats.unavailable_lanes == 0


# ------------------------------------------------------------------ drops


def test_drop_windows_cost_a_retry_not_an_answer(data):
    build_k, build_v, _, _ = data
    sched = FaultSchedule(events=(FaultEvent("drop", 8, 64, drop_rate=1.0),),
                          lease_term_ops=0, seed=3)
    st = open_store(StoreSpec("outback", load_factor=0.85, faults=sched),
                    build_k, build_v)
    for i in range(16):
        r = st.get_batch(build_k[i * 16:(i + 1) * 16])
        assert r.statuses is None or "unavailable" not in r.statuses
        assert bool(r.found.all())
    m = st.meter_totals()
    assert m.drops >= 1 and m.retries >= 1


# ------------------------------------------------------------------ replay


def test_replay_routes_replicas_and_applies_fault_windows(data):
    build_k, build_v, w_k, w_v = data
    tr = Transport()
    st = open_store(_crash_spec(down_s=100e-6), build_k, build_v,
                    transport=tr)
    for i in range(12):
        st.get_batch(build_k[i * 32:(i + 1) * 32])
        st.insert_batch(w_k[i * 8:(i + 1) * 8], w_v[i * 8:(i + 1) * 8])
    for i in range(12):
        st.get_batch(build_k[i * 32:(i + 1) * 32])
    segs = [s for ev in tr.trace if hasattr(ev, "segments")
            for s in ev.segments]
    assert {s.mn for s in segs} == {0, 1}, "multicast never reached MN 1"
    assert any(s.wait_s > 0 for s in segs), "no CN stall reached the trace"
    res = simulate(tr.trace, clients=2, replicas=2)
    assert res.fault_windows and res.fault_windows[0][2] == "mn_crash"
    av = res.availability()
    assert av["schema"] == "outback-availability/v1"
    assert len(av["availability"]) == len(av["t_s"]) == 40
    assert av["fault_windows"]
    # two runs of the same trace are bit-identical
    res2 = simulate(tr.trace, clients=2, replicas=2)
    assert np.array_equal(res.latencies_us, res2.latencies_us)


def test_directory_store_replicates_through_a_split(data):
    """outback-dir at K=2: a crash over a store that *split* during the
    window still resyncs (the restarted replica rebuilds its table list
    from the donor's shipped CN locators)."""
    build_k, build_v, w_k, w_v = data
    sched = FaultSchedule.single_crash(at_op=48, duration_ops=192,
                                       lease_term_ops=0)
    spec = StoreSpec("outback-dir", load_factor=0.85, replicas=2,
                     faults=sched, params={"initial_depth": 1})
    st = open_store(spec, build_k, build_v)
    for i in range(24):  # inserts force splits inside the crash window
        st.insert_batch(w_k[i * 32:(i + 1) * 32], w_v[i * 32:(i + 1) * 32])
        st.get_batch(build_k[:16])
    for _ in range(8):
        st.get_batch(build_k[:32])
    m = st.meter_totals()
    assert m.resyncs >= 1
    g = st.get_batch(w_k[:24 * 32])
    ok = g.found
    assert bool(ok.all())
    assert np.array_equal(g.values[ok], w_v[:24 * 32][ok])
    adapter = st
    while not isinstance(adapter, ReplicaSetAdapter):
        adapter = adapter.inner
    sigs = {_state_sig(r.engine.mn_state()) for r in adapter.replicas}
    assert len(sigs) == 1, "directory replicas diverged through the split"
