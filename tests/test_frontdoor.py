"""The serving front door: singleflight, admission, limits, dormancy.

The FrontDoor sits between tenants and the ``repro.api`` stack, so its
contracts are the serving plane's ground truth: collapsed Gets must
return the leader's exact answer, window hazards must preserve program
order, rejections must be typed answers (never hangs), the upstream-lane
accounting must align 1:1 with the recorded transport trace, and the
default config must be byte-invisible.
"""

import pickle

import numpy as np
import pytest

from repro.api import BatchPolicy, StoreSpec, open_store
from repro.net import Transport
from repro.net.faults import FaultSchedule
from repro.net.replay import simulate_open
from repro.serve import (FrontDoor, FrontDoorConfig, TenantLimit, TenantSpec,
                         TrafficSpec, generate)

N = 8_000


@pytest.fixture(scope="module")
def data():
    from repro.core.store import make_uniform_keys
    keys = make_uniform_keys(N, 3)
    from repro.core.hashing import splitmix64
    return keys, splitmix64(keys)


def _open(keys, vals, **spec_kw):
    tr = Transport()
    spec = StoreSpec("outback", load_factor=0.85,
                     batch=BatchPolicy(window=256), **spec_kw)
    return open_store(spec, keys, vals, transport=tr), tr


# ------------------------------------------------------------ singleflight
def test_collapsed_gets_share_the_leaders_answer(data):
    keys, vals = data
    st, tr = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(singleflight=True, window=64))
    k = int(keys[5])
    recs = [fd.offer("a", "get", k, t_s=i * 1e-6) for i in range(5)]
    miss = fd.offer("b", "get", int(keys[5]) ^ 0x1357_9BDF, t_s=6e-6)
    fd.flush()
    leader, followers = recs[0], recs[1:]
    assert leader.outcome == "ok" and leader.found
    assert leader.result == int(vals[5])
    for f in followers:
        assert f.outcome == "collapsed"
        assert (f.found, f.result, f.lane) == (True, int(vals[5]),
                                               leader.lane)
    assert not miss.found and miss.outcome == "ok"
    # 2 upstream lanes (leader + miss), 4 collapsed, metered as savings
    assert fd.stats()["lanes"] == 2
    m = st.meter_totals()
    assert m.sf_hits == 4
    assert m.saved_round_trips >= 4


def test_singleflight_window_scope(data):
    """Collapse is window-scoped: a flush ends the leader's flight, so
    the next identical Get opens a fresh lane (it is *concurrent*
    duplicates that collapse, not a cache)."""
    keys, vals = data
    st, tr = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(singleflight=True, window=64))
    k = int(keys[9])
    fd.offer("a", "get", k, t_s=0.0)
    fd.flush()
    again = fd.offer("a", "get", k, t_s=1e-6)
    fd.flush()
    assert again.outcome == "ok"  # not collapsed
    assert fd.stats()["lanes"] == 2
    assert st.meter_totals().sf_hits == 0


def test_write_after_collapsed_read_hazard_flushes(data):
    """A write to a key with in-flight (collapsed) Gets closes the window
    first: the Gets see the pre-write value, a later Get sees the new
    one, program order per key is preserved."""
    keys, vals = data
    st, tr = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(singleflight=True, window=4096))
    k = int(keys[11])
    g1 = fd.offer("a", "get", k, t_s=0.0)
    g2 = fd.offer("b", "get", k, t_s=1e-6)
    assert g2.outcome == "collapsed"
    w = fd.offer("a", "update", k, 0xBEEF, t_s=2e-6)
    # the hazard closed the read window before buffering the write
    assert g1.found and g1.result == int(vals[11])
    assert g2.found and g2.result == int(vals[11])
    g3 = fd.offer("b", "get", k, t_s=3e-6)
    fd.flush()
    assert w.outcome == "ok" and w.found
    assert g3.found and g3.result == 0xBEEF
    assert g3.outcome == "ok"  # g2's flight ended with its window


def test_get_then_write_then_get_orders_without_singleflight(data):
    keys, vals = data
    st, tr = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(max_inflight=64, queue_depth=64,
                                       window=4096))
    k = int(keys[13])
    g1 = fd.offer("a", "get", k, t_s=0.0)
    fd.offer("a", "update", k, 0xCAFE, t_s=1e-6)
    g2 = fd.offer("a", "get", k, t_s=2e-6)
    fd.flush()
    assert g1.result == int(vals[13]) and g2.result == 0xCAFE


# ------------------------------------------------- admission + rate limits
def test_admission_sheds_deterministically(data):
    keys, vals = data
    st, tr = _open(keys, vals)
    cfg = FrontDoorConfig(max_inflight=2, queue_depth=2, service_us=10.0,
                          window=64)
    fd = FrontDoor(st, cfg)
    # 8 simultaneous arrivals into 2 lanes x 10us + 2 queue slots:
    # 2 start at t=0, 2 queue, 4 shed — all decided at arrival
    recs = [fd.offer("a", "get", int(keys[i]), t_s=0.0) for i in range(8)]
    fd.flush()
    outcomes = [r.outcome for r in recs]
    assert outcomes == ["ok"] * 4 + ["shed"] * 4
    assert [r.release_s for r in recs[:4]] == \
        pytest.approx([0.0, 0.0, 10e-6, 10e-6])
    # shed requests never reached the stack: 4 lanes, 4 trace ops
    assert fd.stats()["lanes"] == 4
    assert len(fd.lane_arrivals()) == 4
    # rerun is bit-identical (no RNG anywhere on the host path)
    st2, _ = _open(keys, vals)
    fd2 = FrontDoor(st2, cfg)
    recs2 = [fd2.offer("a", "get", int(keys[i]), t_s=0.0) for i in range(8)]
    fd2.flush()
    assert [(r.outcome, r.release_s) for r in recs2] == \
        [(r.outcome, r.release_s) for r in recs]


def test_token_bucket_limits_one_tenant_only(data):
    keys, vals = data
    st, tr = _open(keys, vals)
    cfg = FrontDoorConfig(window=64,
                          limits=(TenantLimit("b", 100_000.0, burst=2.0),))
    fd = FrontDoor(st, cfg)
    a_ok = b_ok = b_lim = 0
    for i in range(40):
        t = i * 1e-6  # 1 Mops offered each: 10x tenant b's bucket
        ra = fd.offer("a", "get", int(keys[i]), t_s=t)
        rb = fd.offer("b", "get", int(keys[40 + i]), t_s=t)
        a_ok += ra.outcome == "ok"
        b_ok += rb.outcome == "ok"
        b_lim += rb.outcome == "ratelimited"
    fd.flush()
    assert a_ok == 40  # unlimited tenant untouched
    # burst 2 up front, then ~0.1 tokens/us over 39us
    assert b_ok + b_lim == 40 and 2 <= b_ok <= 7
    assert fd.stats()["ratelimited"] == b_lim


def test_rejections_are_answers_not_hangs(data):
    keys, vals = data
    st, tr = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(max_inflight=1, queue_depth=0,
                                       service_us=50.0, window=16))
    r1 = fd.offer("a", "get", int(keys[0]), t_s=0.0)
    r2 = fd.offer("a", "get", int(keys[1]), t_s=0.0)
    fd.flush()
    assert r1.outcome == "ok"
    assert r2.outcome == "shed" and not r2.found and r2.lane == -1


def test_unavailable_surfaces_as_typed_outcome(data):
    """RetryLayer's degraded answers become per-request outcomes — for
    leaders *and* their collapsed followers."""
    keys, vals = data
    sched = FaultSchedule.single_crash(at_op=2, duration_ops=4_096,
                                       max_retries=1, lease_term_ops=0)
    st, tr = _open(keys, vals, faults=sched)
    fd = FrontDoor(st, FrontDoorConfig(singleflight=True, window=32))
    recs = []
    for i in range(256):
        recs.append(fd.offer("a", "get", int(keys[i % 16]), t_s=i * 1e-6))
    fd.flush()
    outcomes = {r.outcome for r in recs}
    assert "unavailable" in outcomes
    assert outcomes <= {"ok", "collapsed", "unavailable"}
    for r in recs:
        if r.outcome == "unavailable":
            assert not r.found


# ------------------------------------------------------- config round trip
def test_config_json_round_trip():
    cfg = FrontDoorConfig(max_inflight=8, queue_depth=32, service_us=3.5,
                          singleflight=True, window=128,
                          limits=(TenantLimit("a", 1e5, burst=4.0),))
    back = FrontDoorConfig.from_json_dict(cfg.to_json_dict())
    assert back == cfg
    assert not cfg.passthrough and FrontDoorConfig().passthrough


@pytest.mark.parametrize("bad", [
    dict(max_inflight=-1),
    dict(queue_depth=4),               # queue without admission
    dict(service_us=0.0),
    dict(window=0),
    dict(limits=(TenantLimit("a", 1e5), TenantLimit("a", 2e5))),
    dict(limits=(TenantLimit("a", 0.0),)),
    dict(limits=(TenantLimit("a", 1e5, burst=0.5),)),
])
def test_invalid_configs_raise(bad):
    with pytest.raises(ValueError):
        FrontDoorConfig(**bad).validate()


def test_config_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown FrontDoorConfig"):
        FrontDoorConfig.from_json_dict({"max_inflight": 2, "qps": 8})


def test_offers_must_be_time_ordered(data):
    keys, vals = data
    st, _ = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(singleflight=True))
    fd.offer("a", "get", int(keys[0]), t_s=5e-6)
    with pytest.raises(ValueError, match="non-decreasing"):
        fd.offer("a", "get", int(keys[1]), t_s=4e-6)
    with pytest.raises(ValueError, match="unknown op"):
        fd.offer("a", "scan", int(keys[0]), t_s=6e-6)


# ------------------------------------------------------ telemetry counters
def test_hub_counters_follow_outcomes(data):
    from repro.obs import TelemetryConfig
    keys, vals = data
    st, tr = _open(keys, vals, telemetry=TelemetryConfig(window_ops=1024))
    cfg = FrontDoorConfig(max_inflight=2, queue_depth=1, service_us=25.0,
                          singleflight=True, window=64,
                          limits=(TenantLimit("b", 50_000.0),))
    fd = FrontDoor(st, cfg)
    for i in range(64):
        fd.offer("a", "get", int(keys[i % 4]), t_s=i * 1e-6)
        fd.offer("b", "get", int(keys[8 + i % 4]), t_s=i * 1e-6)
    fd.flush()
    s = fd.stats()
    c = st.hub.counters
    assert c.get("frontdoor.singleflight_hits", 0) == s["collapsed"]
    assert c.get("frontdoor.shed{reason=queue_full}", 0) == s["shed"]
    assert c.get("frontdoor.ratelimited{tenant=b}", 0) == s["ratelimited"]
    admitted = sum(v for k, v in c.items()
                   if k.startswith("frontdoor.admitted"))
    assert admitted == s["ok"] + s["collapsed"]
    hw = [h for name, h in st.hub.hists.items()
          if name.startswith("frontdoor.queue_wait_us")]
    assert hw and sum(h.n for h in hw) == s["ok"]


# ------------------------------------------------------- dormant identity
def test_default_frontdoor_is_byte_invisible(data):
    keys, vals = data
    spec = TrafficSpec(
        tenants=(TenantSpec(name="a", rate_ops_per_s=300_000.0,
                            read_frac=0.7, insert_frac=0.1),),
        duration_s=0.004, seed=21)
    offered = generate(spec, keys)
    snaps, traces, states = [], [], []
    for through_door in (False, True):
        st, tr = _open(keys, vals)
        if through_door:
            fd = FrontDoor(st)  # default config: passthrough
            recs = fd.run(offered)
            assert [r.outcome for r in recs] == ["ok"] * len(recs)
            assert len(fd.lane_arrivals()) == len(recs)
        else:
            for o in offered:
                st.submit(o.op, o.key, o.value)
            st.flush()
        snaps.append(st.meter_totals().snapshot())
        traces.append(tr.trace)
        states.append(pickle.dumps(st.engine.mn_state()))
    assert snaps[0] == snaps[1]
    assert traces[0] == traces[1]
    assert states[0] == states[1]


# --------------------------------------------------- open-loop sim joining
def test_lane_arrivals_align_with_trace(data):
    keys, vals = data
    spec = TrafficSpec(
        tenants=(TenantSpec(name="a", rate_ops_per_s=400_000.0,
                            keyspace=256),),
        duration_s=0.004, seed=33)
    offered = generate(spec, keys)
    st, tr = _open(keys, vals)
    fd = FrontDoor(st, FrontDoorConfig(singleflight=True, window=128))
    recs = fd.run(offered)
    arr = np.asarray(fd.lane_arrivals())
    n_ops = sum(1 for it in tr.trace if type(it).__name__ == "OpEvent")
    assert len(arr) == n_ops == fd.stats()["lanes"]
    res = simulate_open(tr.trace, arr)
    assert len(res.lat_by_op_us) == n_ops
    # every answered request joins a completed lane (a collapsed follower
    # may arrive after its leader's lane finished in sim time — it still
    # joins that lane; the slo bench clamps its latency at zero)
    for r in recs:
        if r.outcome == "ok":
            assert res.completions_by_op_s[r.lane] >= r.release_s
        elif r.outcome == "collapsed":
            assert res.completions_by_op_s[r.lane] > 0.0
    # mismatched arrivals are the documented alignment error
    with pytest.raises(ValueError, match="arrival"):
        simulate_open(tr.trace, arr[:-1])
