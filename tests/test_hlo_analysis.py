"""The trip-count-corrected HLO cost analysis, validated on closed forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyse_text


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile().as_text()


def test_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyse_text(_compile(f, sds, sds))
    assert cost.flops / (2 * 128**3 * 10) == pytest.approx(1.0, rel=0.01)


def test_nested_scan_flops_exact():
    def g(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=10)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyse_text(_compile(g, sds, sds))
    assert cost.flops / (2 * 128**3 * 50) == pytest.approx(1.0, rel=0.01)


def test_grad_remat_flops_ratio():
    def h(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=10)
        return jnp.sum(out)

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyse_text(_compile(jax.grad(h, argnums=1), sds, sds))
    # fwd + recompute + 2 bwd dots = 4x the forward matmul flops
    assert cost.flops / (2 * 128**3 * 10) == pytest.approx(4.0, rel=0.1)


def test_gather_counts_output_not_operand():
    """A gather from a big bank must cost ~2x its OUTPUT, not the bank."""
    def f(bank, idx):
        def body(c, i):
            return c + jnp.sum(bank[i]), None
        out, _ = jax.lax.scan(body, jnp.float32(0), idx)
        return out

    bank = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    idx = jax.ShapeDtypeStruct((8, 2), jnp.int32)
    cost = analyse_text(_compile(f, bank, idx))
    bank_bytes = 512 * 1024 * 4
    # 8 iterations x 2 rows gathered: way below one full bank read per iter
    assert cost.bytes < 2 * bank_bytes


def test_bytes_fused_below_upper():
    def f(x, w):
        return jnp.tanh(x @ w) * 2.0 + 1.0

    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost = analyse_text(_compile(f, sds, sds))
    assert 0 < cost.bytes <= cost.bytes_upper


@pytest.mark.mesh
def test_collective_bytes_multiply_by_trips():
    """psum inside a scan must count once per iteration."""
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.launch.hlo_analysis import analyse_text
        mesh = jax.make_mesh((4,), ("data",))
        def inner(x):
            def body(c, _):
                return jax.lax.psum(c, "data") * 0.5, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out
        fn = shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P())
        sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        txt = jax.jit(fn).lower(sds).compile().as_text()
        cost = analyse_text(txt)
        per = 64 * 64 * 4
        ratio = cost.coll_total / per
        assert 6.5 <= ratio <= 14.5, ratio  # 7 trips (x2 if AR counted in+out)
        print("COLL_OK", ratio)
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "COLL_OK" in out.stdout, out.stderr[-1500:]
