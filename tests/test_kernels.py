"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import split_u64, splitmix64
from repro.core.outback import OutbackShard
from repro.core.store import make_uniform_keys
from repro.kernels import ops, ref
from repro.kernels.fused_norm_matmul import fused_norm_matmul_kernel
from repro.kernels.ludo_lookup import ludo_lookup_kernel
from repro.kernels.paged_attention import (cuckoo_paged_attention_kernel,
                                           paged_attention_kernel)
from repro.kernels.slot_unpack import slot_unpack_kernel


# ------------------------------------------------------------- ludo_lookup
@pytest.fixture(scope="module")
def shard():
    keys = make_uniform_keys(40_000)
    return OutbackShard(keys, splitmix64(keys), load_factor=0.9), keys


@pytest.mark.parametrize("batch,block", [(1024, 256), (4096, 1024), (512, 512)])
def test_ludo_lookup_kernel_vs_ref(shard, batch, block):
    sh, keys = shard
    meta = ops.cn_meta_from(sh)
    lo, hi = split_u64(keys[:batch])
    lo, hi = jnp.asarray(lo), jnp.asarray(hi)
    wa = jnp.asarray(sh.cn.othello.words_a)
    wb = jnp.asarray(sh.cn.othello.words_b)
    seeds = jnp.asarray(sh.cn.seeds)
    b_ref, s_ref = ref.ludo_lookup_ref(lo, hi, wa, wb, seeds, ma=meta["ma"],
                                       mb=meta["mb"], nb=meta["nb"],
                                       seed_a=meta["seed_a"], seed_b=meta["seed_b"])
    b_k, s_k = ludo_lookup_kernel(lo, hi, wa, wb, seeds.astype(jnp.int32),
                                  block=block, interpret=True, **meta)
    np.testing.assert_array_equal(np.asarray(b_k), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))
    # and both agree with the authoritative host locator
    bb, ss = sh.cn.locate(*split_u64(keys[:batch]))
    np.testing.assert_array_equal(np.asarray(b_ref), bb.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(s_ref), ss.astype(np.int32))


# ------------------------------------------------------------- slot_unpack
@pytest.mark.parametrize("n", [2048, 8192])
def test_slot_unpack_kernel_vs_ref(n):
    rng = np.random.default_rng(0)
    s_lo = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    s_hi = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
    outs_k = slot_unpack_kernel(s_lo, s_hi, block=1024, interpret=True)
    outs_r = ref.slot_unpack_ref(s_lo, s_hi)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------- paged attention
def _mk_paged(rng, n_kv, g, d, P, ps, L, seq_len, dtype):
    q = jnp.asarray(rng.standard_normal((n_kv, g, d)), dtype)
    k = jnp.asarray(rng.standard_normal((P, ps, n_kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((P, ps, n_kv, d)), dtype)
    pm = jnp.asarray(rng.choice(P, L, replace=False), jnp.int32)
    return q, k, v, pm


@pytest.mark.parametrize("n_kv,g,d,ps,L,seq_len,dtype", [
    (2, 4, 64, 16, 4, 64, jnp.float32),
    (2, 4, 64, 16, 4, 49, jnp.float32),   # ragged last page
    (4, 2, 128, 32, 8, 250, jnp.float32),
    (1, 8, 64, 16, 2, 32, jnp.bfloat16),
])
def test_paged_attention_kernel_vs_ref(n_kv, g, d, ps, L, seq_len, dtype):
    rng = np.random.default_rng(1)
    q, k, v, pm = _mk_paged(rng, n_kv, g, d, 3 * L, ps, L, seq_len, dtype)
    o_r, m_r, l_r = ref.paged_attention_ref(q, k, v, pm, jnp.int32(seq_len))
    lens = jnp.asarray([seq_len], jnp.int32)
    o_k, m_k, l_k = paged_attention_kernel(q, k, v, pm, lens, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=tol, atol=tol)


def test_cuckoo_paged_attention_matches_ludo():
    """The 2-fetch baseline must produce identical attention — it just moves
    2x the pages. (The perf difference shows up in DMA bytes, not values.)"""
    rng = np.random.default_rng(2)
    n_kv, g, d, ps, L, seq = 2, 4, 64, 16, 4, 60
    q, k, v, pm = _mk_paged(rng, n_kv, g, d, 4 * L, ps, L, seq, jnp.float32)
    # candidates: true page in column `sel`, decoy in the other
    decoy = jnp.asarray(rng.choice(4 * L, L, replace=False), jnp.int32)
    sel = jnp.asarray(rng.integers(0, 2, L), jnp.int32)
    pm2 = jnp.where(sel[:, None] == 0, jnp.stack([pm, decoy], 1),
                    jnp.stack([decoy, pm], 1))
    lens = jnp.asarray([seq], jnp.int32)
    o_l, m_l, l_l = paged_attention_kernel(q, k, v, pm, lens, interpret=True)
    o_c, m_c, l_c = cuckoo_paged_attention_kernel(q, k, v, pm2, sel, lens,
                                                  interpret=True)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_l), rtol=1e-5, atol=1e-5)


def test_flash_combine_partials():
    """Sequence-parallel decode: combining per-range partials == full attention."""
    rng = np.random.default_rng(3)
    n_kv, g, d, ps = 2, 4, 64, 16
    L, seq = 8, 128
    q, k, v, pm = _mk_paged(rng, n_kv, g, d, 3 * L, ps, L, seq, jnp.float32)
    o_full, _, _ = ref.paged_attention_ref(q, k, v, pm, jnp.int32(seq))
    # split the pages into two "devices"
    parts = []
    for sl, off in [(slice(0, 4), 0), (slice(4, 8), 64)]:
        o, m, l = ref.paged_attention_ref(q, k, v, pm[sl], jnp.int32(seq - off if off else 64))
        parts.append((o, m, l))
    # ranges: first device owns tokens [0,64), second [64,128)
    o0, m0, l0 = ref.paged_attention_ref(q, k, v, pm[:4], jnp.int32(64))
    o1, m1, l1 = ref.paged_attention_ref(q, k, v, pm[4:], jnp.int32(64))
    o_c = ref.combine_flash_partials([o0, o1], [m0, m1], [l0, l1])
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_full), rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- fused norm matmul
@pytest.mark.parametrize("S,d,F,dtype,bs,bf", [
    (256, 512, 1024, jnp.float32, 128, 256),
    (512, 256, 512, jnp.float32, 256, 512),
    (128, 1024, 512, jnp.bfloat16, 128, 128),
])
def test_fused_norm_matmul_vs_ref(S, d, F, dtype, bs, bf):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((S, d)), dtype)
    gamma = jnp.asarray(rng.standard_normal((d,)), dtype)
    w = jnp.asarray(rng.standard_normal((d, F)) / np.sqrt(d), dtype)
    out_k = fused_norm_matmul_kernel(x, gamma, w, block_s=bs, block_f=bf,
                                     interpret=True)
    out_r = ref.fused_norm_matmul_ref(x, gamma, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), rtol=tol, atol=tol)


# --------------------------------------------------------------- ops layer
def test_ops_dispatch_ref_on_cpu(shard):
    sh, keys = shard
    meta = ops.cn_meta_from(sh)
    lo, hi = split_u64(keys[:256])
    b, s = ops.ludo_lookup(jnp.asarray(lo), jnp.asarray(hi),
                           jnp.asarray(sh.cn.othello.words_a),
                           jnp.asarray(sh.cn.othello.words_b),
                           jnp.asarray(sh.cn.seeds), meta)
    bb, ss = sh.cn.locate(lo, hi)
    np.testing.assert_array_equal(np.asarray(b), bb.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(s), ss.astype(np.int32))
