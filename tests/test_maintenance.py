"""repro.core.maintenance: vectorized DMPH maintenance vs scalar oracles.

The contract under test is *element-wise equivalence*: the one-shot seed
search must return exactly what the legacy per-bucket 256-seed Python loop
returned (lowest-valid-seed semantics, including the no-seed-found path),
and the batched frontier eviction must satisfy every placement invariant
the per-key random walk satisfied.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ludo, maintenance
from repro.core.hashing import popcount32, split_u64, splitmix64
from repro.core.store import make_uniform_keys


def _keys(n, seed=1):
    return make_uniform_keys(n, seed)


def _gathered(n, seed, lf=0.9):
    """A real placement's gathered buckets: the seed-search input."""
    keys = _keys(n, seed)
    lo, hi = split_u64(keys)
    nb = max(1, int(np.ceil(n / (4.0 * lf))))
    b0, b1 = ludo.candidate_buckets(lo, hi, nb)
    bucket_of, _ = maintenance.cuckoo_place(
        b0.astype(np.int64), b1.astype(np.int64), nb, seed)
    g_lo, g_hi, valid, _, _ = maintenance.gather_buckets(lo, hi, bucket_of, nb)
    return g_lo, g_hi, valid


# ------------------------------------------------------------- seed search
@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=4, max_value=3000), st.integers(0, 6))
def test_one_shot_seeds_match_reference(n, seed):
    g_lo, g_hi, valid = _gathered(n, seed)
    s_vec, ok_vec = maintenance.one_shot_seeds(g_lo, g_hi, valid)
    s_ref, ok_ref = maintenance.seed_search_reference(g_lo, g_hi, valid)
    np.testing.assert_array_equal(ok_vec, ok_ref)
    np.testing.assert_array_equal(s_vec, s_ref)  # lowest valid seed


def test_one_shot_seeds_tiling_is_pure_schedule():
    """Any tile size gives the same (lowest) seeds as one 256-wide shot."""
    g_lo, g_hi, valid = _gathered(1200, 3)
    base, ok = maintenance.one_shot_seeds(g_lo, g_hi, valid, tile=256)
    assert ok.all()
    for tile in (1, 7, 32, 100):
        s, o = maintenance.one_shot_seeds(g_lo, g_hi, valid, tile=tile)
        np.testing.assert_array_equal(s, base)
        assert o.all()


def test_no_seed_found_path_matches_reference():
    """Duplicate keys in a bucket can never reach 4 distinct slots: both
    searches must report the bucket unresolved (not mis-hash it)."""
    keys = _keys(8, 2)
    lo, hi = split_u64(keys)
    g_lo = np.zeros((2, 4), np.uint32)
    g_hi = np.zeros((2, 4), np.uint32)
    g_lo[0], g_hi[0] = lo[0], hi[0]  # bucket 0: the same key 4 times
    g_lo[1, :4], g_hi[1, :4] = lo[4:8], hi[4:8]  # bucket 1: fine
    valid = np.ones((2, 4), bool)
    s_vec, ok_vec = maintenance.one_shot_seeds(g_lo, g_hi, valid)
    s_ref, ok_ref = maintenance.seed_search_reference(g_lo, g_hi, valid)
    np.testing.assert_array_equal(ok_vec, [False, True])
    np.testing.assert_array_equal(ok_vec, ok_ref)
    assert s_vec[1] == s_ref[1]


def test_build_raises_on_unseedable_bucket():
    """The LudoBuildError contract survives the vectorized search."""
    keys = _keys(12, 5)
    lo, hi = split_u64(keys)
    lo[1], hi[1] = lo[0], hi[0]  # duplicate key pair
    bucket_of = np.zeros(12, np.int64)  # force everyone into bucket 0...
    bucket_of[4:] = -1  # ...but only 4 keys placed (incl. the duplicate)
    with pytest.raises(ludo.LudoBuildError):
        ludo._find_seeds(lo, hi, bucket_of, 1)
    with pytest.raises(ludo.LudoBuildError):
        ludo._find_seeds(lo, hi, bucket_of, 1, reference=True)


def test_find_bucket_seed_matches_batch_and_legacy_semantics():
    keys = _keys(64, 9)
    lo, hi = split_u64(keys)
    # single-bucket view == batch view == brute-force reference
    k_lo = np.zeros((16, 4), np.uint32)
    k_hi = np.zeros((16, 4), np.uint32)
    counts = np.zeros(16, np.int64)
    for b in range(16):
        k = 1 + (b % 4)
        k_lo[b, :k] = lo[4 * b:4 * b + k]
        k_hi[b, :k] = hi[4 * b:4 * b + k]
        counts[b] = k
    batch = maintenance.find_bucket_seeds_batch(k_lo, k_hi, counts)
    for b in range(16):
        k = int(counts[b])
        single = ludo.find_bucket_seed(k_lo[b, :k], k_hi[b, :k])
        assert single == int(batch[b])
        # legacy loop semantics: lowest seed with k distinct slots
        from repro.core.hashing import slot_hash
        for s in range(single):
            assert np.unique(slot_hash(k_lo[b, :k], k_hi[b, :k],
                                       np.uint32(s))).size < k
    # duplicates -> no seed
    dup_lo = np.asarray([lo[0]] * 2, np.uint32)
    dup_hi = np.asarray([hi[0]] * 2, np.uint32)
    assert ludo.find_bucket_seed(dup_lo, dup_hi) is None
    assert ludo.find_bucket_seed(np.zeros(0, np.uint32),
                                 np.zeros(0, np.uint32)) == 0


# -------------------------------------------------------------- popcount
def test_popcount32_matches_naive():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 1 << 32, 4096, dtype=np.uint64).astype(np.uint32)
    naive = np.asarray([bin(int(v)).count("1") for v in x], np.uint32)
    np.testing.assert_array_equal(popcount32(x), naive)
    assert int(popcount32(np.uint32(0))) == 0
    assert int(popcount32(np.uint32(0xFFFFFFFF))) == 32


# -------------------------------------------------------- cuckoo placement
@settings(deadline=None, max_examples=8)
@given(st.integers(min_value=16, max_value=4000),
       st.sampled_from([0.7, 0.9, 0.95]), st.integers(0, 4))
def test_frontier_eviction_invariants(n, lf, seed):
    keys = _keys(n, seed + 1)
    lo, hi = split_u64(keys)
    nb = max(1, int(np.ceil(n / (4.0 * lf))))
    b0, b1 = ludo.candidate_buckets(lo, hi, nb)
    b0l, b1l = b0.astype(np.int64), b1.astype(np.int64)
    bucket_of, fallback = maintenance.cuckoo_place(b0l, b1l, nb, seed)
    placed = bucket_of >= 0
    # every placed key sits in one of its two candidate buckets
    assert ((bucket_of[placed] == b0l[placed])
            | (bucket_of[placed] == b1l[placed])).all()
    # occupancy <= 4 everywhere
    assert np.bincount(bucket_of[placed], minlength=nb).max(initial=0) <= 4
    # fallback is exactly the unplaced set
    np.testing.assert_array_equal(np.sort(np.nonzero(~placed)[0]), fallback)
    # deterministic for a fixed seed
    again, fb2 = maintenance.cuckoo_place(b0l, b1l, nb, seed)
    np.testing.assert_array_equal(bucket_of, again)
    np.testing.assert_array_equal(fallback, fb2)


def test_frontier_eviction_actually_evicts():
    """At a load where the greedy passes cannot finish, the frontier walk
    must still place (nearly) everything — same bar the reference meets."""
    n = 6000
    keys = _keys(n, 7)
    lo, hi = split_u64(keys)
    nb = int(np.ceil(n / (4.0 * 0.95)))
    b0, b1 = ludo.candidate_buckets(lo, hi, nb)
    b0l, b1l = b0.astype(np.int64), b1.astype(np.int64)
    # greedy alone leaves a tail at lf 0.95 (precondition for the test)
    occ = np.full((nb, 4), -1, np.int64)
    fill = np.zeros(nb, np.int64)
    bo = np.full(n, -1, np.int64)
    rest, _ = maintenance._greedy_pass(np.arange(n, dtype=np.int64), b0l,
                                       occ, fill, bo)
    rest, _ = maintenance._greedy_pass(rest, b1l[rest], occ, fill, bo)
    assert rest.size > 0
    vec_bo, vec_fb = maintenance.cuckoo_place(b0l, b1l, nb, 7)
    ref_bo, ref_fb = maintenance.cuckoo_place_reference(b0l, b1l, nb, 7)
    assert vec_fb.size <= max(8, ref_fb.size + 8)  # no systematic give-up
    assert (vec_bo >= 0).sum() >= (ref_bo >= 0).sum() - 8


def test_gather_buckets_rejects_overfull():
    keys = _keys(8, 1)
    lo, hi = split_u64(keys)
    with pytest.raises(ValueError):
        maintenance.gather_buckets(lo, hi, np.zeros(8, np.int64), 2)


def test_build_reference_flag_same_invariants():
    keys = _keys(3000, 13)
    lo, hi = split_u64(keys)
    for reference in (False, True):
        b = ludo.build(lo, hi, load_factor=0.92, reference=reference)
        assert b.ok
        pos = b.bucket.astype(np.int64) * 4 + b.slot
        assert np.unique(pos).size == keys.size
        bb, ss = b.cn.locate(lo, hi)
        np.testing.assert_array_equal(bb, b.bucket)
        np.testing.assert_array_equal(ss, b.slot)
