"""Batched Makeup-Get parity (ROADMAP "Batched Makeup-Get").

``OutbackShard._resolve_makeups`` now runs the §4.3.1 miss path
vectorised — one CN locate, one ``OverflowCache.lookup_batch`` probe and
one (m, 4) bucket-slot scan — while the legacy per-lane loop is kept as
``_resolve_makeups_reference``.  These tests pin the two lane-identical
under post-``s_slow`` overflow pressure: same answers, same CN seed
refreshes, byte-identical meter totals and transport traces.
"""

import numpy as np
import pytest

from repro.core.hashing import split_u64, splitmix64
from repro.core.outback import OutbackShard
from repro.core.store import make_uniform_keys
from repro.net import Transport

N = 4000


def _pressured_shard(transport=None):
    """A shard driven past the §4.4 ``s_slow`` trigger: tight table, small
    overflow cache, then fresh inserts until overflow pressure is real."""
    keys = make_uniform_keys(N, 7)
    vals = splitmix64(keys)
    sh = OutbackShard(keys, vals, load_factor=0.95, overflow_frac=0.05,
                      rng_seed=3, transport=transport)
    fresh = splitmix64(np.arange(1, 600, dtype=np.uint64) + np.uint64(9 << 40))
    for k in fresh:
        if sh.must_stop():
            break
        sh.insert(int(k), int(splitmix64(np.uint64([k]))[0]))
    return sh, keys, fresh


@pytest.fixture(scope="module")
def queries():
    keys = make_uniform_keys(N, 7)
    fresh = splitmix64(np.arange(1, 600, dtype=np.uint64) + np.uint64(9 << 40))
    absent = splitmix64(np.arange(1, 64, dtype=np.uint64) + np.uint64(1 << 45))
    # slot residents + overflow residents + absent keys: every makeup case
    return np.concatenate([keys[:800], fresh[:400], absent])


def test_overflow_lookup_batch_matches_scalar(queries):
    sh, _, _ = _pressured_shard()
    assert sh.overflow.size > 20, "workload sized for real overflow pressure"
    assert sh.needs_resize(), "post-s_slow is the scenario under test"
    lo, hi = split_u64(queries)
    addr_b, probes_b = sh.overflow.lookup_batch(lo, hi)
    for j in range(queries.shape[0]):
        addr, probes = sh.overflow.lookup(int(lo[j]), int(hi[j]))
        assert (addr if addr is not None else -1) == addr_b[j]
        assert probes == probes_b[j]


def test_resolve_makeups_matches_reference(queries):
    tr_vec, tr_ref = Transport(), Transport()
    a, _, _ = _pressured_shard(transport=tr_vec)
    b, _, _ = _pressured_shard(transport=tr_ref)

    out_vec = a.get_batch(queries, resolve_makeup=True)
    raw = b.get_batch(queries, resolve_makeup=False)
    assert int((~np.asarray(raw[2])).sum()) > 200, \
        "workload sized for a real makeup wave"
    out_ref = b._resolve_makeups_reference(queries, *raw, xp=np)

    for got, want in zip(out_vec, out_ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # identical accounting: meter totals, trace (cont-attachment order
    # included), and the §4.3.1 CN seed refreshes
    assert a.meter.snapshot() == b.meter.snapshot()
    assert tr_vec.trace == tr_ref.trace
    np.testing.assert_array_equal(a.cn.seeds, b.cn.seeds)


def test_resolve_makeups_skip_mask_respected():
    sh, keys, _ = _pressured_shard()
    q = keys[:64]
    raw = sh.get_batch(q, resolve_makeup=False)
    before = sh.meter.snapshot()
    skip = np.ones(q.shape[0], dtype=bool)  # every lane masked out
    v_lo, v_hi, match = sh._resolve_makeups(q, *raw, xp=np, skip=skip)
    assert sh.meter.snapshot() == before  # nothing resolved, nothing spent
    np.testing.assert_array_equal(np.asarray(match), np.asarray(raw[2]))


def test_batched_get_through_api_under_pressure(queries):
    """End-to-end: the api-level resolved Get over a pressured shard equals
    the scalar protocol answers (overflow residents included)."""
    from repro.api import StoreSpec, open_store
    sh, keys, fresh = _pressured_shard()
    st = open_store(StoreSpec("outback", load_factor=0.95,
                              params={"overflow_frac": 0.05}, rng_seed=3),
                    keys, splitmix64(keys))
    for k in fresh:
        if st.engine.must_stop():
            break
        st.insert(int(k), int(splitmix64(np.uint64([k]))[0]))
    res = st.get_batch(queries)
    for j in range(0, queries.shape[0], 37):  # spot-check vs scalar walks
        want = sh.get(int(queries[j])).value
        got = int(res.values[j]) if res.found[j] else None
        assert got == want
