"""repro.net: the discrete-event RDMA transport simulator.

Covers the PR-2 acceptance criteria: determinism under a fixed seed,
latency orderings (Outback <= two-sided baselines, one-sided RACE ~2x
Outback's p50), closed-loop saturation with RPC-Dummy as the upper bound,
doorbell batching, resize-dip windows, the Makeup-Get continuation rule,
and that ``transport=None`` keeps every metered path byte-for-byte
unchanged.
"""

import numpy as np
import pytest

from repro.core.baselines import ClusterKVS, DummyKVS, MicaKVS, RaceKVS
from repro.core.hashing import splitmix64
from repro.core.meter import CommMeter
from repro.core.outback import OutbackShard
from repro.core.store import OutbackStore, make_uniform_keys
from repro.net import (CX3, CX6, OpEvent, ResizeMark, Segment, Simulator,
                       Transport, simulate)

N = 20_000


@pytest.fixture(scope="module")
def data():
    keys = make_uniform_keys(N, 7)
    return keys, splitmix64(keys)


@pytest.fixture(scope="module")
def queries(data):
    keys, _ = data
    return keys[np.random.default_rng(3).integers(0, N, 4096)]


def _trace(cls, data, queries, **kw):
    keys, vals = data
    tr = Transport()
    kvs = cls(keys, vals, transport=tr, **kw)
    kvs.get_batch(queries)
    return tr


@pytest.fixture(scope="module")
def traces(data, queries):
    return {
        "outback": _trace(OutbackShard, data, queries, load_factor=0.85),
        "race": _trace(RaceKVS, data, queries),
        "mica": _trace(MicaKVS, data, queries),
        "cluster": _trace(ClusterKVS, data, queries),
        "dummy": _trace(DummyKVS, data, queries),
    }


# ------------------------------------------------------------ engine basics
def test_simulator_deterministic_tie_break():
    sim = Simulator()
    seen = []
    for i in range(5):
        sim.schedule(1.0, lambda i=i: seen.append(i))  # all at t=1.0
    sim.run()
    assert seen == [0, 1, 2, 3, 4]


def test_simulation_is_deterministic(traces):
    t = traces["outback"].trace
    a = simulate(t, clients=7, window=2)
    b = simulate(t, clients=7, window=2)
    assert a.percentiles() == b.percentiles()
    assert np.array_equal(a.latencies_us, b.latencies_us)
    assert a.seconds == b.seconds


def test_trace_replay_counts_every_op(traces):
    for name, tr in traces.items():
        res = simulate(tr.trace, clients=4)
        assert res.n_ops == len(tr) >= 4096, name


# ------------------------------------------------- the paper's lat orderings
def test_latency_outback_leq_two_sided(traces):
    p50 = {k: simulate(tr.trace, clients=1).percentile_us(50)
           for k, tr in traces.items()}
    assert p50["outback"] <= p50["mica"]
    assert p50["outback"] <= p50["cluster"]


def test_latency_race_two_dependent_round_trips(traces):
    p_out = simulate(traces["outback"].trace, clients=1).percentile_us(50)
    p_race = simulate(traces["race"].trace, clients=1).percentile_us(50)
    assert 1.6 <= p_race / p_out <= 2.6  # ~2x: two dependent RTs


def test_latency_cx3_slower_than_cx6(traces):
    t = traces["outback"].trace
    assert (simulate(t, clients=1, service=CX3).percentile_us(50)
            > simulate(t, clients=1, service=CX6).percentile_us(50))


# -------------------------------------------------------- closed-loop scale
def test_throughput_saturates_with_clients(traces):
    t = traces["outback"].trace
    tput = [simulate(t, clients=c).tput_mops for c in (1, 4, 16, 64)]
    assert tput[1] > 3.5 * tput[0]  # linear region
    assert tput[3] == pytest.approx(tput[2], rel=0.15)  # saturated
    lat = [simulate(t, clients=c).percentile_us(50) for c in (1, 64)]
    assert lat[1] > lat[0]  # queueing shows up past saturation


def test_dummy_is_the_upper_bound(traces):
    tput = {k: simulate(tr.trace, clients=64).tput_mops
            for k, tr in traces.items()}
    for k in ("outback", "race", "mica", "cluster"):
        assert tput[k] < tput["dummy"], (k, tput)
    # and the MN-compute ordering survives the trip through simulated time
    assert tput["mica"] < tput["outback"]


def test_mn_threads_scale_rpc_throughput(traces):
    t = traces["mica"].trace
    one = simulate(t, clients=64, mn_threads=1).tput_mops
    two = simulate(t, clients=64, mn_threads=2).tput_mops
    assert two > 1.6 * one


def test_doorbell_batching_pays_at_depth(traces):
    t = traces["outback"].trace
    on = simulate(t, clients=1, window=8, doorbell=True)
    off = simulate(t, clients=1, window=8, doorbell=False)
    assert on.tput_mops > 1.1 * off.tput_mops
    # at window=1 there is nothing to coalesce: identical schedules
    a = simulate(t, clients=2, window=1, doorbell=True)
    b = simulate(t, clients=2, window=1, doorbell=False)
    assert a.percentiles() == b.percentiles()


# ------------------------------------------------------------- resize window
def test_resize_mark_opens_dip_window(data):
    keys, vals = data
    tr = Transport()
    store = OutbackStore(keys[:8000], vals[:8000], load_factor=0.85,
                         transport=tr)
    q = keys[:2048]
    store.get_batch(q)
    h = store.begin_split(0)
    for _ in range(6):
        store.get_batch(q)  # stale table serves during the rebuild
    h.build()
    h.finish()
    store.get_batch(q)
    res = simulate(tr.trace, clients=8)
    assert len(res.resize_windows) == 1
    w0, w1 = res.resize_windows[0]
    assert 0 < w0 < w1 < res.seconds
    before = res.tput_in_window(0, w0)
    during = res.tput_in_window(w0, w1)
    assert during < 0.8 * before  # the Fig.-17 dip


def test_overlapping_resize_windows_keep_slowdown_open():
    """Back-to-back splits: the MN slowdown must persist until the LAST
    rebuild window closes, not reset when the first one does."""
    op = OpEvent(segments=(Segment(req_bytes=64, resp_bytes=64, mn_reads=2),))
    trace = [op] * 64 + [ResizeMark(4000), op, ResizeMark(4000)] + [op] * 4096
    res = simulate(trace, clients=8)
    assert len(res.resize_windows) == 2
    (a0, a1), (b0, b1) = res.resize_windows
    assert b0 < a1 < b1  # the windows genuinely overlap
    # while both/either are open, service runs at the slow rate
    assert res.tput_in_window(b0, b1) < 0.8 * res.tput_in_window(0, a0)
    assert res.tput_in_window(b1, res.seconds) > res.tput_in_window(b0, b1)


@pytest.mark.mesh
@pytest.mark.parametrize("variant", ["outback", "race"])
def test_sharded_mesh_rides_the_clock(data, variant):
    """build_sharded(transport=...) + make_get_fn meter the mesh Get path
    into the same trace the scalar protocols use."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import sharded_kvs as skv
    from repro.core.hashing import split_u64

    keys, vals = data
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tr = Transport()
    st = skv.build_sharded(keys, vals, num_shards=1, data_parallel=1,
                           transport=tr)
    arrays = skv.place_state(mesh, st)
    fn, _ = skv.make_get_fn(mesh, st, 1024, variant=variant)
    q = keys[np.random.default_rng(5).integers(0, N, 1024)]
    qlo, qhi = split_u64(q)
    qs = NamedSharding(mesh, P(("data", "model")))
    v_lo, v_hi, match = fn(jax.device_put(jnp.asarray(qlo), qs),
                           jax.device_put(jnp.asarray(qhi), qs), *arrays)
    assert np.asarray(match).all()
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | \
        np.asarray(v_lo)
    np.testing.assert_array_equal(got, splitmix64(q))
    assert len(tr) == 1024 and st.meter.ops == 1024
    res = simulate(tr.trace, clients=4)
    assert res.n_ops == 1024
    rts = 2 if variant == "race" else 1
    assert all(len(e.segments) == rts for e in tr.trace
               if isinstance(e, OpEvent))


# ------------------------------------------- meter -> trace translation rules
def test_makeup_get_rides_as_continuation(data):
    keys, vals = data
    tr = Transport()
    sh = OutbackShard(keys[:2000], vals[:2000], load_factor=0.85,
                      transport=tr)
    missing = int(splitmix64(np.uint64([1 << 50]))[0])
    sh.get(missing)  # miss: Get + Makeup-Get, 2 meter ops, ONE logical op
    assert sh.meter.ops == 2 and sh.meter.round_trips == 2
    ops = [e for e in tr.trace if isinstance(e, OpEvent)]
    assert len(ops) == 1 and len(ops[0].segments) == 2


def test_batch_makeups_attach_to_distinct_ops(data):
    keys, vals = data
    tr = Transport()
    sh = OutbackShard(keys[:2000], vals[:2000], load_factor=0.85,
                      transport=tr)
    # force overflow residents -> batched Get resolves them via Makeup-Get
    extra = splitmix64(np.arange(1, 200, dtype=np.uint64) + np.uint64(1 << 40))
    for k in extra:
        sh.insert(int(k), int(k) & (2**62 - 1))
    tr.reset()
    _, _, match = sh.get_batch(extra, resolve_makeup=True)
    assert np.asarray(match).all()
    two_rt = [e for e in tr.trace
              if isinstance(e, OpEvent) and len(e.segments) >= 2]
    assert len(two_rt) >= 2  # spread over distinct ops, not stacked on one
    assert max(len(e.segments) for e in tr.trace) <= 3


def test_one_sided_bytes_not_padded():
    m = CommMeter()
    m.add(1, rts=1, req=16, resp=32)                  # two-sided: padded
    assert (m.req_bytes, m.resp_bytes) == (64, 64)
    m.reset()
    m.add(1, rts=1, req=16, resp=32, one_sided=True)  # READ payload: raw
    assert (m.req_bytes, m.resp_bytes) == (16, 32)


def test_add_attach_charges_same_op():
    m = CommMeter()
    m.add(1, rts=1, req=8, resp=8, mn_reads=2)
    m.add(0, rts=1, req=8, resp=8, mn_cmp=3,
          attach=True)  # extra RT on the same op
    assert m.ops == 1 and m.round_trips == 2 and m.mn_cmp_ops == 3
    assert m.req_bytes == 2 * 64


def test_add_zero_without_attach_is_a_noop():
    """Dynamically-computed lane counts may reach 0 (e.g. a fully cache-hit
    batch): that must add nothing and must not mutate the trace."""
    from repro.net import Transport
    tr = Transport()
    m = CommMeter()
    m.sink = tr
    m.add(2, rts=1, req=8, resp=8)
    snap = m.snapshot()
    m.add(0, rts=1, req=8, resp=8)  # empty batch remainder: no-op
    assert m.snapshot() == snap
    assert len(tr) == 2 and all(len(e.segments) == 1 for e in tr.trace)


def test_fully_cached_batch_adds_no_phantom_round_trip(data):
    from repro.core.cn_cache import CNKeyCache
    keys, vals = data
    sh = OutbackShard(keys, vals, load_factor=0.85,
                      cn_cache=CNKeyCache(1 << 20))
    hot = keys[:64]
    for _ in range(3):
        sh.get_batch(hot)  # admit the whole set
    before = sh.meter.snapshot()
    sh.get_batch(hot)  # 100% cache hits: zero wire traffic
    after = sh.meter.snapshot()
    assert after["round_trips"] == before["round_trips"]
    assert after["req_bytes"] == before["req_bytes"]
    assert after["ops"] == before["ops"] + 64


# ------------------------------------------------- transport=None unchanged
def test_transport_none_identical_meters(data, queries):
    keys, vals = data
    plain = OutbackShard(keys, vals, load_factor=0.85)
    wired = OutbackShard(keys, vals, load_factor=0.85, transport=Transport())
    plain.get_batch(queries)
    wired.get_batch(queries)
    assert plain.meter.snapshot() == wired.meter.snapshot()


def test_session_store_rides_the_clock():
    from repro.serve import KVSessionStore
    tr = Transport()
    ss = KVSessionStore(cn_cache_budget_bytes=32 << 10, bootstrap_keys=1024,
                        transport=tr)
    blob = bytes(range(256)) * 8
    ss.put(1, blob)
    # v2: the park is *submitted*, riding the store's BatchPolicy window —
    # nothing on the (recorded) wire until a doorbell rings
    assert len(tr) == 0 and ss.store._n_pending > 0
    ss.flush()
    n_after_put = len(tr)
    assert n_after_put > 0  # inserts were recorded at the flush
    assert ss.get(1) == blob
    assert len(tr) > n_after_put  # ...and so were the reads
    res = simulate(tr.trace, clients=4)
    assert res.n_ops == len(tr) and res.percentile_us(50) > 0
    # the recorded flush replays as one coalesced doorbell window
    res_pol = simulate(tr.trace, clients=1, window="policy")
    res_sync = simulate(tr.trace, clients=1, window=1)
    assert res_pol.n_ops == res_sync.n_ops
    assert res_pol.seconds < res_sync.seconds


def test_trace_segments_wellformed(traces):
    for name, tr in traces.items():
        for e in tr.trace:
            if isinstance(e, ResizeMark):
                continue
            assert isinstance(e, OpEvent) and len(e.segments) >= 1, name
            for s in e.segments:
                assert isinstance(s, Segment)
                assert s.req_bytes >= 0 and s.resp_bytes >= 0
                if s.one_sided:
                    assert s.mn_hash == s.mn_cmp == 0  # no MN CPU for READs
