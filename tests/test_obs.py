"""Telemetry-plane contracts (ISSUE 7): deterministic histograms, the
op-clock hub, span annotations, exporters, and — load-bearing — the
dormant-plane byte-identity guarantee: a store assembled with telemetry
must leave meters, recorded traces, and final MN state exactly as a
store assembled without it.
"""

import json
import pickle

import numpy as np
import pytest

from repro.api import (BatchPolicy, StoreSpec, TelemetryConfig, open_store)
from repro.core.hashing import splitmix64
from repro.core.meter import CommMeter
from repro.core.store import make_uniform_keys
from repro.net import FaultSchedule, Transport
from repro.obs import (HIST_SPEC, LogHistogram, SPAN_KINDS, TELEMETRY_SCHEMA,
                       TelemetryHub, chrome_trace, telemetry_rows,
                       validate_telemetry_rows)
from repro.obs.hist import (N_BUCKETS, bucket_hi, bucket_index,
                            bucket_indices, bucket_lo)


def _dataset(n=2048, seed=5):
    keys = make_uniform_keys(n, seed)
    return keys, splitmix64(keys)


def _spec(telemetry=None, **kw):
    return StoreSpec("outback", load_factor=0.85, telemetry=telemetry, **kw)


# ------------------------------------------------------------- histograms
def test_bucket_edges_contain_their_values():
    rng = np.random.default_rng(0)
    vals = np.concatenate([rng.uniform(0, 3, 200),
                           rng.uniform(1, 2**40, 200),
                           [0.0, 0.5, 1.0, 2.0, 2.0**44, 2.0**50]])
    for v in vals:
        i = bucket_index(float(v))
        assert 0 <= i < N_BUCKETS
        if i < N_BUCKETS - 1:  # overflow bucket clamps
            assert bucket_lo(i) <= v < bucket_hi(i)
    # the vectorised path is exactly the scalar path
    assert np.array_equal(bucket_indices(vals),
                          [bucket_index(float(v)) for v in vals])


def test_histogram_merge_is_associative_and_weighted_record_matches():
    rng = np.random.default_rng(1)
    parts = [rng.integers(0, 10_000, 300) for _ in range(3)]
    hs = []
    for p in parts:
        h = LogHistogram()
        h.record_many(p)
        hs.append(h)
    left = hs[0].copy().merge(hs[1]).merge(hs[2])
    right = hs[0].copy().merge(hs[1].copy().merge(hs[2]))
    assert left == right and left.n == 900
    # weighted vectorised recording == scalar repeated recording
    a, b = LogHistogram(), LogHistogram()
    vals = rng.integers(0, 5000, 200)
    w = rng.integers(0, 4, 200)
    for v, k in zip(vals, w):
        a.record(int(v), int(k))
    b.record_many(vals, weights=w)
    assert a == b


def test_record_range_matches_elementwise_recording():
    # the flush path's dense-run shortcut must be bit-identical to
    # recording every integer of the range individually
    rng = np.random.default_rng(3)
    cases = [(0, 1), (0, 5), (-3, 2), (-5, -1), (5, 5), (1023, 2048),
             (2**44 - 5, 2**44 + 5)]
    cases += [tuple(sorted(rng.integers(-10, 200_000, 2)))
              for _ in range(50)]
    acc_a, acc_b = LogHistogram(), LogHistogram()
    for a, b in cases:
        h1, h2 = LogHistogram(), LogHistogram()
        h1.record_range(a, b)
        h2.record_many(np.arange(a, b))
        assert h1 == h2, (a, b)
        assert h1.total() == h1.n
        acc_a.record_range(a, b)          # and accumulation on one
        acc_b.record_many(np.arange(a, b))  # histogram stays identical
    assert acc_a == acc_b


def test_histogram_json_round_trip_and_spec_guard():
    h = LogHistogram()
    h.record_many(np.random.default_rng(2).integers(0, 10**6, 500))
    d = json.loads(json.dumps(h.to_json_dict(), sort_keys=True))
    assert LogHistogram.from_json_dict(d) == h
    bad = dict(d, spec={"scheme": "other"})
    with pytest.raises(ValueError, match="spec mismatch"):
        LogHistogram.from_json_dict(bad)


def test_percentile_stays_in_observed_range():
    h = LogHistogram()
    h.record_many([100.0] * 50)
    assert h.percentile(50) == 100.0  # min/max bound the bucket midpoint
    h.record_many(np.linspace(10, 1000, 100))
    for q in (1, 50, 99, 99.9):
        assert 10 <= h.percentile(q) <= 1000


# ------------------------------------------------------- config and spec
def test_telemetry_config_round_trip_and_validation():
    cfg = TelemetryConfig(window_ops=128, spans_max=16)
    assert TelemetryConfig.from_json_dict(cfg.to_json_dict()) == cfg
    with pytest.raises(ValueError, match="window_ops"):
        TelemetryConfig(window_ops=0).validate()
    with pytest.raises(ValueError, match="unknown"):
        TelemetryConfig.from_json_dict({"window_ops": 4, "bogus": 1})


def test_store_spec_carries_telemetry_through_json():
    spec = _spec(TelemetryConfig(window_ops=64))
    d = json.loads(json.dumps(spec.to_json_dict()))
    back = StoreSpec.from_json_dict(d)
    assert back.telemetry == TelemetryConfig(window_ops=64)
    assert StoreSpec.from_json_dict(_spec().to_json_dict()).telemetry is None


# ------------------------------------------------------- dormant identity
def test_dormant_plane_is_byte_identical():
    """Meters, recorded trace, and final MN state must not notice the hub."""
    keys, vals = _dataset()
    q = keys[np.random.default_rng(7).integers(0, 1024, 512)]
    snaps, traces, states = [], [], []
    for telemetry in (None, TelemetryConfig(window_ops=64)):
        tr = Transport()
        st = open_store(_spec(telemetry,
                              batch=BatchPolicy(window=128,
                                                order="relaxed")),
                        keys[:1024], vals[:1024], transport=tr)
        for i in range(0, 512, 128):
            st.get_batch(q[i:i + 128])
        st.insert_batch(keys[1024:1088], vals[1024:1088])
        st.update_batch(keys[:32], vals[:32])
        st.delete_batch(keys[32:48])
        st.flush()
        snaps.append(st.meter_totals().snapshot())
        traces.append(tr.trace)
        states.append(pickle.dumps(st.engine.mn_state()))
    assert snaps[0] == snaps[1]
    assert traces[0] == traces[1]
    assert states[0] == states[1], "telemetry perturbed the final MN state"


def test_seeded_rerun_is_bit_identical():
    """Same spec + same op stream → byte-identical JSONL and trace JSON."""
    outs = []
    for _ in range(2):
        keys, vals = _dataset()
        tr = Transport()
        st = open_store(_spec(TelemetryConfig(window_ops=64),
                              batch=BatchPolicy(window=64,
                                                order="relaxed")),
                        keys[:1024], vals[:1024], transport=tr)
        for i in range(0, 1024, 64):
            st.get_batch(keys[i:i + 64])
        st.insert_batch(keys[1024:1056], vals[1024:1056])
        st.flush()
        rows = telemetry_rows(st.telemetry)
        validate_telemetry_rows(rows)
        outs.append((
            "\n".join(json.dumps(r, sort_keys=True) for r in rows),
            json.dumps(chrome_trace(tr.trace, clients=2), sort_keys=True)))
    assert outs[0][0] == outs[1][0]
    assert outs[0][1] == outs[1][1]


# --------------------------------------------------------- clock and spans
def test_snapshot_cadence_follows_the_op_clock():
    keys, vals = _dataset()
    st = open_store(_spec(TelemetryConfig(window_ops=100),
                          batch=BatchPolicy(window=64, order="relaxed")),
                    keys[:1024], vals[:1024])
    for i in range(0, 640, 64):
        st.get_batch(keys[i:i + 64])
    hub = st.telemetry
    assert hub.clock == 640
    assert [s["clock"] for s in hub.snapshots] == [100, 200, 300, 400,
                                                   500, 600]
    # snapshots are cumulative: counters never decrease window to window
    for a, b in zip(hub.snapshots, hub.snapshots[1:]):
        for k, v in a["counters"].items():
            assert b["counters"].get(k, 0) >= v


def test_flush_spans_carry_layer_annotations():
    keys, vals = _dataset()
    st = open_store(_spec(TelemetryConfig(),
                          batch=BatchPolicy(window=32, order="relaxed")),
                    keys[:1024], vals[:1024])
    for i in range(64):
        st.submit("get", int(keys[i]))
    st.flush()
    st.insert(int(keys[0]) ^ 0x5A5A, 9)  # scalar convenience → its own span
    hub = st.telemetry
    spans = list(hub.spans)
    assert all(s.kind in SPAN_KINDS for s in spans)
    flushes = [s for s in spans if s.kind == "flush"]
    assert len(flushes) >= 2
    for s in flushes:
        assert s.op == "get" and s.trigger in ("window", "explicit")
        assert s.ann["coalesced"] >= 1
        assert "queue_wait_ops" in s.ann
        # MeterLayer annotated the wire cost of the flush it ran under
        assert s.ann["round_trips"] >= 1
        assert s.ann["req_bytes"] > 0
    assert any(s.kind == "scalar" for s in spans)
    assert hub.counters["ops{op=get}"] == 64
    assert hub.counters["ops{op=insert}"] == 1
    assert hub.counters["pipe.flushes{trigger=window}"] == 2


def test_span_deque_is_bounded_and_numbered():
    hub = TelemetryHub(TelemetryConfig(spans_max=4))
    for i in range(10):
        hub.begin_span("flush", "get", 1, "window")
    assert hub.spans_opened == 10
    assert len(hub.spans) == 4
    assert [s.span_id for s in hub.spans] == [6, 7, 8, 9]


# -------------------------------------------- failure-plane instrumentation
def test_crash_run_lands_on_replica_dims_and_retry_counters():
    keys, vals = _dataset(4096)
    sched = FaultSchedule.single_crash(at_op=256, duration_ops=256,
                                      down_s=100e-6, lease_term_ops=128)
    st = open_store(_spec(TelemetryConfig(window_ops=128),
                          replicas=2, faults=sched),
                    keys[:2048], vals[:2048])
    for i in range(0, 2048, 64):
        st.get_batch(keys[i:i + 64])
    st.insert_batch(keys[2048:2112], vals[2048:2112])
    hub = st.telemetry
    c = hub.counters
    assert c.get("replica.failovers", 0) >= 1
    assert c.get("retry.backoff_rounds", 0) >= 1
    assert any(k.startswith("replica.resyncs{mn=") for k in c)
    # per-replica wire dims (the CN ledger only counts attribute-style
    # fault bookkeeping, so its mn=cn sink stays silent here)
    assert "wire.events{mn=0}" in c and "wire.events{mn=1}" in c
    assert "replica.write_lanes{mn=0}" in c
    rows = telemetry_rows(hub)
    validate_telemetry_rows(rows)


def test_sharded_and_directory_stores_tag_shard_dims():
    keys, vals = _dataset(4096)
    st = open_store(StoreSpec("sharded", telemetry=TelemetryConfig(),
                              params={"num_shards": 2}),
                    keys[:2048], vals[:2048])
    st.get_batch(keys[:256])
    c = st.telemetry.counters
    # per-shard sinks fire on the wire path (the host-side ledger meter
    # only aggregates, so its shard=host sink stays silent on pure gets)
    assert "wire.events{shard=0}" in c and "wire.events{shard=1}" in c

    st = open_store(StoreSpec("outback-dir", load_factor=0.85,
                              telemetry=TelemetryConfig()),
                    keys[:1024], vals[:1024])
    st.get_batch(keys[:256])
    st.insert_batch(keys[1024:3072], vals[1024:3072])  # pressure → splits
    c = st.telemetry.counters
    assert "wire.events{shard=dir}" in c
    shard_keys = [k for k in c if k.startswith("wire.events{shard=")
                  and "dir" not in k and "host" not in k]
    assert shard_keys, "per-table sinks never fired"
    if st.engine.resize_events:  # split successors inherit sinks
        assert len(shard_keys) >= 2


# --------------------------------------------------------------- exporters
def test_validator_rejects_malformed_exports():
    keys, vals = _dataset()
    st = open_store(_spec(TelemetryConfig(window_ops=64)),
                    keys[:1024], vals[:1024])
    st.get_batch(keys[:256])
    rows = telemetry_rows(st.telemetry)
    validate_telemetry_rows(rows)
    with pytest.raises(ValueError, match="schema"):
        validate_telemetry_rows([dict(rows[0], schema="nope")] + rows[1:])
    with pytest.raises(ValueError, match="meta"):
        validate_telemetry_rows(rows[1:] + rows[:1])
    snap = next(i for i, r in enumerate(rows) if r["row"] == "snapshot")
    bad = [dict(r) for r in rows]
    bad[snap]["clock"] = 7  # not a window multiple
    with pytest.raises(ValueError, match="multiple"):
        validate_telemetry_rows(bad)
    with pytest.raises(ValueError, match="total"):
        validate_telemetry_rows([r for r in rows if r["row"] != "total"])


def test_chrome_trace_is_perfetto_shaped():
    keys, vals = _dataset()
    tr = Transport()
    st = open_store(_spec(batch=BatchPolicy(window=64, order="relaxed")),
                    keys[:1024], vals[:1024], transport=tr)
    for i in range(0, 512, 64):
        st.get_batch(keys[i:i + 64])
    doc = chrome_trace(tr.trace, clients=2)
    ev = doc["traceEvents"]
    assert {e["name"] for e in ev if e.get("ph") == "M"} >= {
        "process_name", "thread_name"}
    ops = [e for e in ev if e["ph"] == "X" and e["name"] == "op"]
    rts = [e for e in ev if e["ph"] == "X" and e["name"].startswith("rt")]
    assert len(ops) == 512 and len(rts) >= len(ops)
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in ops)
    assert any(e["ph"] == "i" and e["name"] == "doorbell" for e in ev)
    busy = [e for e in ev if e.get("pid") == 2 and e["ph"] == "X"]
    assert busy, "MN busy slices missing"
    json.dumps(doc)  # must be directly serialisable


def test_record_spans_is_a_pure_observation():
    from repro.net.replay import simulate
    keys, vals = _dataset()
    tr = Transport()
    st = open_store(_spec(batch=BatchPolicy(window=64, order="relaxed")),
                    keys[:1024], vals[:1024], transport=tr)
    st.get_batch(keys[:256])
    plain = simulate(tr.trace, clients=2)
    spanned = simulate(tr.trace, clients=2, record_spans=True)
    assert plain.percentiles() == spanned.percentiles()
    assert plain.n_ops == spanned.n_ops and plain.seconds == spanned.seconds
    assert spanned.op_spans and spanned.server_spans
    assert not plain.op_spans  # recording off → nothing retained


# ------------------------------------------------------------- meter sinks
def test_comm_meter_sink_fan_out_and_back_compat():
    class Tap:
        def __init__(self):
            self.events = []

        def on_meter_add(self, n, **kw):
            self.events.append((n, kw.get("rts", 0)))

    m = CommMeter()
    a, b = Tap(), Tap()
    m.sink = a                      # v1 single-sink property still works
    assert m.sink is a and m.sinks == [a]
    m.add_sink(b)
    m.add_sink(b)                   # idempotent by identity
    assert m.sinks == [a, b]
    m.add(4, rts=2, req=64, resp=64)
    assert a.events == [(4, 2)] and b.events == [(4, 2)]
    m.sink = None                   # property setter replaces the list
    assert m.sinks == []
    # sinks never leak into accounting identity
    m2 = CommMeter()
    m2.add(4, rts=2, req=64, resp=64)
    assert m.snapshot() == m2.snapshot()


def test_hub_merge_folds_counters_and_hists_exactly():
    h1, h2 = TelemetryHub(), TelemetryHub()
    h1.count("x", 3, op="get")
    h2.count("x", 4, op="get")
    h1.hist("lat").record_many([1, 10, 100])
    h2.hist("lat").record_many([5, 50])
    h1.merge(h2)
    assert h1.counters["x{op=get}"] == 7
    assert h1.hists["lat"].n == 5
    ref = LogHistogram()
    ref.record_many([1, 10, 100, 5, 50])
    assert h1.hists["lat"] == ref


def test_schema_tag_is_stable():
    # the CI lane greps for this exact tag; changing it is a schema bump
    assert TELEMETRY_SCHEMA == "outback-telemetry/v1"
    assert HIST_SPEC["n_buckets"] == 353
