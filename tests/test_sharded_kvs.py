"""Distributed KVS: single-device mesh in-process + 8-device subprocess."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sharded_kvs as skv
from repro.core.hashing import split_u64, splitmix64
from repro.core.store import make_uniform_keys


def _run(mesh_shape, num_shards, n=20_000, batch=2048, variant="outback"):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    keys = make_uniform_keys(n)
    vals = splitmix64(keys)
    st = skv.build_sharded(keys, vals, num_shards=num_shards,
                           data_parallel=mesh_shape[0], load_factor=0.85)
    arrays = skv.place_state(mesh, st)
    ndev = mesh_shape[0] * mesh_shape[1]
    fn, _ = skv.make_get_fn(mesh, st, batch // ndev, variant=variant)
    q = keys[np.random.default_rng(3).integers(0, n, batch)]
    qlo, qhi = split_u64(q)
    qs = NamedSharding(mesh, P(("data", "model")))
    qlo = jax.device_put(jnp.asarray(qlo), qs)
    qhi = jax.device_put(jnp.asarray(qhi), qs)
    v_lo, v_hi, match = fn(qlo, qhi, *arrays)
    match = np.asarray(match)
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(v_lo)
    return match, got, splitmix64(q)


@pytest.mark.mesh
@pytest.mark.parametrize("variant", ["outback", "race"])
def test_sharded_kvs_single_device(variant):
    match, got, expect = _run((1, 1), 1, variant=variant)
    assert match.all()
    np.testing.assert_array_equal(got, expect)


def test_bin_by_roundtrip():
    tgt = jnp.asarray(np.random.default_rng(0).integers(0, 4, 128), jnp.int32)
    idxmap = skv.bin_by(tgt, 4, 64)
    x = jnp.arange(128, dtype=jnp.uint32) + 100
    binned = skv.take(x, idxmap, 0xFFFFFFFF)
    back = skv.unbin(idxmap, binned, 128, 0)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_bin_by_capacity_drop():
    # all targets equal, capacity 8 -> exactly 8 survive
    tgt = jnp.zeros(32, jnp.int32)
    idxmap = skv.bin_by(tgt, 2, 8)
    assert int((idxmap < 32).sum()) == 8


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import sharded_kvs as skv
    from repro.core.hashing import split_u64, splitmix64
    from repro.core.store import make_uniform_keys
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    keys = make_uniform_keys(40_000)
    vals = splitmix64(keys)
    st = skv.build_sharded(keys, vals, num_shards=4, data_parallel=2)
    arrays = skv.place_state(mesh, st)
    B = 8192
    fn, _ = skv.make_get_fn(mesh, st, B // 8)
    q = keys[np.random.default_rng(0).integers(0, keys.shape[0], B)]
    qlo, qhi = split_u64(q)
    qs = NamedSharding(mesh, P(("data", "model")))
    qlo = jax.device_put(jnp.asarray(qlo), qs)
    qhi = jax.device_put(jnp.asarray(qhi), qs)
    v_lo, v_hi, match = fn(qlo, qhi, *arrays)
    assert np.asarray(match).all(), np.asarray(match).mean()
    got = (np.asarray(v_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(v_lo)
    assert (got == splitmix64(q)).all()
    print("MULTIDEV_OK")
""")


@pytest.mark.slow
@pytest.mark.mesh
def test_sharded_kvs_eight_devices_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in out.stdout, out.stderr[-2000:]
