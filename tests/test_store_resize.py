"""OutbackStore §4.4 resize window: freeze, FALSE'd mutations, replay."""

import numpy as np
import pytest

from repro.core.hashing import splitmix64
from repro.core.store import OutbackStore, make_uniform_keys


def _store(n=4000, **kw):
    keys = make_uniform_keys(n, seed=5)
    return OutbackStore(keys, splitmix64(keys), load_factor=0.85, **kw), keys


def _val(k):
    return int(splitmix64(np.uint64([k]))[0])


def _fresh_keys(n, tag):
    return splitmix64(np.arange(1, n + 1, dtype=np.uint64)
                      + np.uint64(tag << 48))


def test_resize_window_buffers_and_replays_inserts():
    store, keys = _store()
    h = store.begin_split(0)
    # window open: the frozen table FALSE's inserts, the store buffers them
    new_keys = _fresh_keys(50, 7)
    frozen = [store.insert(int(k), _val(int(k)) >> 1) for k in new_keys]
    assert all(c == "frozen" for c in frozen)
    assert len(store._buffer) == 50
    # Gets keep being served from the stale table throughout
    assert store.get(int(keys[0])).value == _val(int(keys[0]))
    h.build()
    assert store.get(int(keys[1])).value == _val(int(keys[1]))
    h.finish()
    # replayed: every buffered insert is now live
    for k in new_keys:
        assert store.get(int(k)).value == _val(int(k)) >> 1
    assert store.resize_events[-1].buffered_mutations == 50
    assert store._buffer == []


def test_resize_window_buffers_and_replays_deletes():
    store, keys = _store()
    victims = keys[:20]
    h = store.begin_split(0)
    results = [store.delete(int(k)) for k in victims]
    assert not any(results)  # FALSE'd during the window (paper semantics)
    for k in victims:  # still readable from the stale table
        assert store.get(int(k)).value == _val(int(k))
    h.build()
    h.finish()
    for k in victims:  # replay applied the deletes to the fresh tables
        assert store.get(int(k)).value is None
    live = [k for k in keys[20:100]]
    for k in live:
        assert store.get(int(k)).value == _val(int(k))


def test_split_doubles_directory_and_preserves_all_keys():
    store, keys = _store()
    assert store.global_depth == 0 and len(store.tables) == 1
    n_before = store.n_keys
    store._split(0)
    assert store.global_depth == 1 and len(store.tables) == 2
    assert store.n_keys == n_before
    idx = np.random.default_rng(0).integers(0, len(keys), 500)
    for k in keys[idx]:
        assert store.get(int(k)).value == _val(int(k))


def test_split_without_directory_doubling():
    store, keys = _store()
    store._split(0)
    store._split(0)  # doubles again: directory now has 4 entries, 3 tables
    assert store.global_depth == 2
    # one table still has local depth 1 -> splitting it must NOT double
    lagging = store.local_depth.index(1)
    store._split(lagging)
    assert store.global_depth == 2
    assert len(store.directory) == 4
    for k in keys[:300]:
        assert store.get(int(k)).value == _val(int(k))


def test_only_one_resize_in_flight():
    store, _ = _store()
    store.begin_split(0)
    with pytest.raises(RuntimeError):
        store.begin_split(0)


def test_organic_resize_from_insert_pressure():
    """Inserting past s_slow triggers a split transparently; nothing lost."""
    store, keys = _store(2000)
    extra = _fresh_keys(2500, 3)
    for k in extra:
        store.insert(int(k), _val(int(k)) >> 2)
    assert store.resize_events, "insert pressure should have split"
    rng = np.random.default_rng(1)
    for k in extra[rng.integers(0, len(extra), 400)]:
        assert store.get(int(k)).value == _val(int(k)) >> 2
    for k in keys[rng.integers(0, len(keys), 400)]:
        assert store.get(int(k)).value == _val(int(k))


def test_resize_replay_with_cn_cache_keeps_coherence():
    """The full interaction: hot keys cached, resize window mutations,
    invalidation at the swap, replay through the cache hooks."""
    store, keys = _store(3000, cn_cache_budget_bytes=64 << 10)
    hot = keys[:100]
    for _ in range(3):
        for k in hot:
            store.get(int(k))
    h = store.begin_split(0)
    # updates during the window hit the stale table AND refresh the cache
    for k in hot[:10]:
        assert store.update(int(k), 1234)
    new_keys = _fresh_keys(30, 9)
    for k in new_keys:
        store.insert(int(k), 555)
    h.build()
    h.finish()
    # post-swap: updates visible... (update raced the snapshot: the cache
    # was invalidated, so reads must agree with the tables, whatever they
    # hold — no stale cache serving)
    for k in hot[:10]:
        got = store.get(int(k)).value
        direct = store._table(int(k))._get_mn(int(k)).value
        assert got == direct
    for k in new_keys:  # buffered inserts replayed
        assert store.get(int(k)).value == 555
    for k in hot[10:]:  # untouched hot keys still correct
        assert store.get(int(k)).value == _val(int(k))
