"""Store-level split timing: scalar vs batched (ROADMAP "Store-level
split timing").

The batched ``OutbackStore.insert_batch`` re-checks the §4.4 split trigger
per *chunk* (bounded by ``_insert_chunk_len``: never more than
``SPLIT_CHECK_CHUNK`` ops, never more than a third of the table's overflow
capacity), while the scalar stream checks after every insert.  A split can
therefore land up to one chunk later in the batched stream.  This test
pins the contract:

* both streams split, at op indices **at most one chunk apart**;
* the final MN state is identical — same directory depth, same live keys,
  same answers for every key (original, pre-split and post-split inserts);
* CN-cache coherence holds through the differently-timed splits;
* the meter divergence is **bounded by the chunk**: the two runs differ
  only because ops near the boundary land pre-split in one stream and
  post-split in the other (frozen FALSE'd bookkeeping + buffered replay),
  never by more than a chunk's worth of round trips.
"""

import numpy as np

from repro.api import BatchPolicy, StoreSpec, open_store
from repro.core.hashing import splitmix64
from repro.core.store import OutbackStore, make_uniform_keys

N = 3000
CHUNK = OutbackStore.SPLIT_CHECK_CHUNK


def _fresh(n: int) -> np.ndarray:
    return splitmix64(np.arange(1, n + 1, dtype=np.uint64) + np.uint64(31 << 40))


def _drive(batched: bool):
    keys = make_uniform_keys(N, 11)
    vals = splitmix64(keys)
    spec = StoreSpec("outback-dir", load_factor=0.85,
                     cache_budget_bytes=32 << 10,
                     batch=BatchPolicy(window=CHUNK, order="relaxed"))
    st = open_store(spec, keys, vals)
    fresh = _fresh(2 * N)
    fvals = splitmix64(fresh)
    i = 0
    while not st.engine.resize_events and i < fresh.shape[0]:
        if batched:
            st.insert_batch(fresh[i:i + CHUNK], fvals[i:i + CHUNK])
            i += CHUNK
        else:
            for j in range(i, min(i + CHUNK, fresh.shape[0])):
                st.insert(int(fresh[j]), int(fvals[j]))
            i += CHUNK
        st.get_batch(keys[:128])  # keep the CN cache warm across the split
    assert st.engine.resize_events, "workload sized to force a split"
    return st, keys, fresh[:i], fvals[:i]


def test_split_timing_and_final_state_parity():
    s_st, keys, s_fresh, s_fvals = _drive(batched=False)
    b_st, _, b_fresh, _ = _drive(batched=True)

    # ---- split timing: batched lands at most one chunk later -----------
    ev_s = s_st.engine.resize_events[0]
    ev_b = b_st.engine.resize_events[0]
    # both streams interleave one 128-key Get batch per chunk, so op
    # indices are comparable; the batched trigger is only evaluated at
    # chunk boundaries (and insert_batch counts its ops up front), so it
    # may trail the scalar trigger — but never by more than one chunk of
    # inserts plus the interleaved reads
    assert ev_b.step >= ev_s.step - CHUNK
    assert ev_b.step - ev_s.step <= 2 * (CHUNK + 128)
    # the split happened on (almost) the same table content: the rebuilt
    # key counts differ by at most the ops of one chunk
    assert abs(ev_b.table_keys - ev_s.table_keys) <= CHUNK

    # ---- final MN state: same directory shape, same answers ------------
    assert s_st.engine.global_depth == b_st.engine.global_depth
    assert len(s_st.engine.tables) == len(b_st.engine.tables)
    n_ins = min(s_fresh.shape[0], b_fresh.shape[0])
    probe = np.concatenate([keys, s_fresh[:n_ins]])
    rs = s_st.get_batch(probe)
    rb = b_st.get_batch(probe)
    np.testing.assert_array_equal(rs.found, rb.found)
    np.testing.assert_array_equal(rs.values, rb.values)
    # coherence: the CN caches survived their (differently-timed) splits
    # without serving stale answers — checked against the engine truth
    for j in range(0, probe.shape[0], 101):
        want = s_st.engine.get(int(probe[j]))
        got = int(rs.values[j]) if rs.found[j] else None
        assert got == want.value

    # ---- documented meter divergence: bounded by the chunk -------------
    ms = s_st.meter_totals()
    mb = b_st.meter_totals()
    # both streams executed the same op multiset up to one chunk of
    # boundary inserts (frozen FALSE'd + replayed vs accepted directly);
    # each such op costs at most 2 RTs (FALSE + replay), so the RT gap is
    # bounded by ~2 chunks of inserts plus one interleaved read batch
    assert abs(ms.round_trips - mb.round_trips) <= 2 * (CHUNK + 128), (
        ms.round_trips, mb.round_trips)
    # and neither run lost ops: op counts line up within the same bound
    assert abs(ms.ops - mb.ops) <= 2 * (CHUNK + 128)


def test_batched_split_chunk_never_breaches_overflow_headroom():
    """The chunk the split check bounds is a third of the table's overflow
    capacity at most — a batch cannot sail past ``s_stop`` between two
    checks (regression guard for the §4.4 hard limit)."""
    keys = make_uniform_keys(1024, 3)
    st = OutbackStore(keys, splitmix64(keys), load_factor=0.85)
    table = st.tables[0]
    assert st._insert_chunk_len(table) <= max(1, int(0.35 * table.overflow.cap))
    assert st._insert_chunk_len(table) <= OutbackStore.SPLIT_CHECK_CHUNK
