"""The open-loop traffic generator: seeded determinism and spec hygiene.

The ``slo`` suite's claims are only reproducible if the arrival schedule
is a pure function of (TrafficSpec, build keys) — bit-identical reruns,
JSON specs that round-trip exactly, and arrival processes whose long-run
behaviour matches their knobs.
"""

import json

import numpy as np
import pytest

from repro.serve import TenantSpec, TrafficSpec, generate
from repro.serve.traffic import OP_KINDS

N = 4_000


@pytest.fixture(scope="module")
def keys():
    from repro.core.store import make_uniform_keys
    return make_uniform_keys(N, 7)


def _spec(**kw):
    base = dict(
        tenants=(TenantSpec(name="a", rate_ops_per_s=200_000.0,
                            read_frac=0.8, insert_frac=0.05),
                 TenantSpec(name="b", rate_ops_per_s=100_000.0,
                            arrival="mmpp", keyspace=512, hot_salt=3)),
        duration_s=0.05, seed=9, diurnal_amp=0.4, diurnal_period_s=0.02)
    base.update(kw)
    return TrafficSpec(**base)


# ------------------------------------------------------------ determinism
def test_seeded_rerun_is_bit_identical(keys):
    a = generate(_spec(), keys)
    b = generate(_spec(), keys)
    assert a == b  # Offered is a frozen dataclass: full field equality


def test_seed_changes_the_schedule(keys):
    a = generate(_spec(), keys)
    b = generate(_spec(seed=10), keys)
    assert a != b


def test_schedule_shape(keys):
    offered = generate(_spec(), keys)
    assert offered, "a 50ms x 300kops/s spec generated nothing"
    ts = [o.t_s for o in offered]
    assert ts == sorted(ts)
    assert all(0.0 <= t < 0.05 for t in ts)
    assert {o.tenant for o in offered} == {"a", "b"}
    assert {o.op for o in offered} <= set(OP_KINDS)
    for o in offered:
        if o.op == "get":
            assert o.value is None
        else:
            assert o.value is not None


def test_rates_land_near_spec(keys):
    offered = generate(_spec(), keys)
    per = {"a": 0, "b": 0}
    for o in offered:
        per[o.tenant] += 1
    # Poisson over 50ms: expect ~10k and ~5k, allow generous slack
    assert per["a"] == pytest.approx(10_000, rel=0.1)
    assert per["b"] == pytest.approx(5_000, rel=0.15)
    mix = [o.op for o in offered if o.tenant == "a"]
    assert mix.count("get") / len(mix) == pytest.approx(0.8, abs=0.05)
    assert mix.count("insert") / len(mix) == pytest.approx(0.05, abs=0.02)


def test_keyspace_restricts_to_hot_set(keys):
    offered = generate(_spec(), keys)
    build = set(keys.tolist())
    b_keys = {o.key for o in offered if o.tenant == "b" and o.op != "insert"}
    assert len(b_keys) <= 512
    assert b_keys <= build
    # fresh inserts never collide with the build set
    for o in offered:
        if o.op == "insert":
            assert o.key not in build


def test_shared_salt_shares_the_hot_set(keys):
    def hot(salt_a, salt_b):
        spec = _spec(tenants=(
            TenantSpec(name="a", rate_ops_per_s=100_000.0, keyspace=64,
                       hot_salt=salt_a),
            TenantSpec(name="b", rate_ops_per_s=100_000.0, keyspace=64,
                       hot_salt=salt_b)))
        out = {"a": set(), "b": set()}
        for o in generate(spec, keys):
            out[o.tenant].add(o.key)
        return out
    same = hot(1, 1)
    assert same["a"] == same["b"]  # 64-key hot set, 5k draws each: saturated
    diff = hot(1, 2)
    assert diff["a"] != diff["b"]


# ------------------------------------------------------------------- JSON
def test_spec_json_round_trip():
    spec = _spec()
    back = TrafficSpec.from_json(spec.to_json())
    assert back == spec
    assert json.loads(spec.to_json()) == spec.to_json_dict()


def test_spec_rejects_unknown_fields():
    d = _spec().to_json_dict()
    d["qps"] = 3
    with pytest.raises(ValueError, match="unknown TrafficSpec"):
        TrafficSpec.from_json_dict(d)
    d = _spec().to_json_dict()
    d["tenants"][0]["color"] = "red"
    with pytest.raises(ValueError, match="unknown TenantSpec"):
        TrafficSpec.from_json_dict(d)


@pytest.mark.parametrize("bad", [
    dict(duration_s=0.0),
    dict(diurnal_amp=1.0),
    dict(diurnal_amp=0.3, diurnal_period_s=0.0),
    dict(tenants=()),
    dict(tenants=(TenantSpec(name="a", rate_ops_per_s=1e5),
                  TenantSpec(name="a", rate_ops_per_s=1e5))),
    dict(tenants=(TenantSpec(name="a", rate_ops_per_s=0.0),)),
    dict(tenants=(TenantSpec(name="a", rate_ops_per_s=1e5,
                             read_frac=0.5, insert_frac=0.6),)),
    dict(tenants=(TenantSpec(name="a", rate_ops_per_s=1e5,
                             arrival="pareto"),)),
    dict(tenants=(TenantSpec(name="a", rate_ops_per_s=1e5, arrival="mmpp",
                             burst_factor=1.0),)),
    dict(tenants=(TenantSpec(name="a", rate_ops_per_s=1e5, arrival="mmpp",
                             burst_factor=4.0, burst_frac=0.5),)),
])
def test_invalid_specs_raise(bad, keys):
    with pytest.raises(ValueError):
        generate(_spec(**bad), keys)


def test_scaled(keys):
    spec = _spec()
    double = spec.scaled(2.0)
    assert double.total_rate() == pytest.approx(2 * spec.total_rate())
    assert double.duration_s == spec.duration_s
    assert [t.name for t in double.tenants] == [t.name for t in spec.tenants]
    n1 = len(generate(spec, keys))
    n2 = len(generate(double, keys))
    assert n2 == pytest.approx(2 * n1, rel=0.1)


# --------------------------------------------------- arrival process shape
def test_mmpp_is_burstier_than_poisson(keys):
    def cv2(arrival):
        spec = TrafficSpec(
            tenants=(TenantSpec(name="a", rate_ops_per_s=200_000.0,
                                arrival=arrival, burst_factor=8.0,
                                burst_frac=0.1, burst_mean_s=0.002),),
            duration_s=0.1, seed=3)
        ts = np.array([o.t_s for o in generate(spec, keys)])
        gaps = np.diff(ts)
        return gaps.var() / gaps.mean() ** 2
    assert cv2("poisson") == pytest.approx(1.0, abs=0.2)  # exponential gaps
    assert cv2("mmpp") > 1.5  # squared coefficient of variation >> poisson


def test_diurnal_modulation_shifts_mass(keys):
    spec = TrafficSpec(
        tenants=(TenantSpec(name="a", rate_ops_per_s=200_000.0),),
        duration_s=0.1, seed=5, diurnal_amp=0.8, diurnal_period_s=0.1)
    ts = np.array([o.t_s for o in generate(spec, keys)])
    # rate ~ 1 + 0.8*sin(2*pi*t/T): the first half-period carries most ops
    first = (ts < 0.05).sum()
    assert first / len(ts) > 0.6
