"""Training substrate + serving engine + paged cache tests."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cache import CuckooPageTable, LudoPageTable
from repro.configs import TrainConfig, get_config
from repro.models.lm import LM
from repro.serve import Engine, Request
from repro.train import (Prefetcher, SyntheticLM, init_state, latest_step,
                         lr_schedule, make_train_step, restore, save)
from repro.train.optimizer import state_pspecs, zero1_spec
from jax.sharding import PartitionSpec as P


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("llama3.2-1b", reduced=True)
    model = LM(cfg)
    return cfg, model, model.init(0)


@pytest.mark.slow
def test_train_step_decreases_loss_on_learnable_data(tiny):
    cfg, model, params = tiny
    tcfg = TrainConfig(total_steps=40, warmup_steps=4, learning_rate=2e-3)
    state = init_state(params)
    step = jax.jit(make_train_step(model, tcfg))  # no donation: params fixture is shared
    # learnable: constant token sequence
    toks = jnp.ones((4, 32), jnp.int32) * 7
    batch = {"tokens": toks, "labels": toks}
    first = None
    for _ in range(25):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first * 0.5  # memorizes a constant stream


@pytest.mark.slow
def test_grad_accum_matches_full_batch(tiny):
    cfg, model, params = tiny
    src = SyntheticLM(cfg.vocab_size, 32, 8)
    batch = {k: jnp.asarray(v) for k, v in src.global_batch_at(0).items()}
    t0 = TrainConfig(microbatch=0, learning_rate=1e-3)
    t1 = TrainConfig(microbatch=4, learning_rate=1e-3)
    s0, m0 = jax.jit(make_train_step(model, t0))(init_state(params), batch)
    s1, m1 = jax.jit(make_train_step(model, t1))(init_state(params), batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=5e-2)
    # parameters move in the same direction at comparable magnitude
    d0 = jax.tree.leaves(s0.params)[0] - jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(s1.params)[0] - jax.tree.leaves(params)[0]
    cos = float(jnp.sum(d0 * d1) / (jnp.linalg.norm(d0) * jnp.linalg.norm(d1)))
    assert cos > 0.9


@pytest.mark.slow
def test_checkpoint_restart_is_bitexact(tiny):
    cfg, model, params = tiny
    tcfg = TrainConfig(total_steps=20, warmup_steps=2)
    step = jax.jit(make_train_step(model, tcfg))
    src = SyntheticLM(cfg.vocab_size, 32, 4)
    state = init_state(params)
    for i in range(3):
        state, _ = step(state, {k: jnp.asarray(v)
                                for k, v in src.global_batch_at(i).items()})
    d = tempfile.mkdtemp()
    save(d, int(state.step), state.tree())
    # continue 2 more steps
    stateA = state
    for i in (3, 4):
        stateA, mA = step(stateA, {k: jnp.asarray(v)
                                   for k, v in src.global_batch_at(i).items()})
    # restart from checkpoint, replay the same data (deterministic pipeline)
    t = restore(d, state.tree())
    stateB = dataclasses.replace(init_state(params), params=t["params"],
                                 m=t["m"], v=t["v"],
                                 step=jnp.asarray(t["step"]))
    for i in (3, 4):
        stateB, mB = step(stateB, {k: jnp.asarray(v)
                                   for k, v in src.global_batch_at(i).items()})
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]), rtol=1e-6)


def test_checkpoint_retention_and_latest():
    d = tempfile.mkdtemp()
    tree = {"a": jnp.arange(4.0)}
    for s in (1, 2, 3, 4, 5):
        save(d, s, tree, retain=2)
    assert latest_step(d) == 5
    import os
    kept = [x for x in os.listdir(d) if x.startswith("step_")]
    assert len(kept) == 2


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 64), st.integers(1, 1024))
def test_lr_schedule_bounds(warm, total):
    tcfg = TrainConfig(warmup_steps=warm, total_steps=max(total, warm + 1),
                       learning_rate=1e-3)
    for s in [0, warm, total // 2, total]:
        lr = float(lr_schedule(tcfg, jnp.int32(s)))
        assert 0.0 <= lr <= 1e-3 + 1e-9


@settings(deadline=None, max_examples=30)
@given(st.sampled_from([(16, 2048), (8, 64, 64), (2048,), (3, 5)]),
       st.integers(2, 16))
def test_zero1_spec_validity(shape, data):
    spec = zero1_spec(P(), shape, data)
    for ax, dim in zip(spec, shape):
        if ax == "data":
            assert dim % data == 0


def test_data_pipeline_deterministic_replay():
    src = SyntheticLM(1000, 16, 4, seed=3)
    a = src.global_batch_at(7)
    b = src.global_batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    p1 = Prefetcher(src)
    p1.seek(5)
    first = p1.get()
    np.testing.assert_array_equal(first["tokens"],
                                  src.global_batch_at(5)["tokens"])


def test_engine_serves_all(tiny):
    cfg, model, params = tiny
    eng = Engine(model, params, lanes=2, max_seq=48)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new=4))
    eng.run()
    assert eng.stats.finished == 4


def test_engine_park_resume_preserves_state():
    cfg = get_config("rwkv6-1.6b", reduced=True)
    model = LM(cfg)
    eng = Engine(model, model.init(0), lanes=2, max_seq=64)
    eng.submit(Request(rid=1, prompt=[4, 5, 6], max_new=30))
    for _ in range(3):
        eng.step()
    before = np.asarray(eng.cache["length"])[0]
    rid = eng.park(0)
    lane = eng.resume(rid)
    after = np.asarray(eng.cache["length"])[lane]
    assert after == before


@pytest.mark.slow
def test_engine_park_resume_via_kvs_session_store():
    """Lane state actually travels through the Outback KVS; the second
    resume of the same session reads through the CN cache."""
    from repro.serve import KVSessionStore
    cfg = get_config("rwkv6-1.6b", reduced=True)
    model = LM(cfg)
    ss = KVSessionStore(cn_cache_budget_bytes=256 << 10)
    eng = Engine(model, model.init(0), lanes=2, max_seq=64, session_store=ss)
    eng.submit(Request(rid=1, prompt=[4, 5, 6], max_new=30))
    for _ in range(3):
        eng.step()
    before = np.asarray(eng.cache["length"])[0]
    rid = eng.park(0)
    lane = eng.resume(rid)
    assert np.asarray(eng.cache["length"])[lane] == before
    rid = eng.park(lane)
    h0 = ss.cache_stats.hits
    eng.resume(rid)
    assert ss.cache_stats.hits > h0


# ------------------------------------------------------------- paged cache
def test_ludo_page_table_full_protocol():
    pt = LudoPageTable(2048)
    seqs = {s: 12 + s for s in range(6)}
    expect = {}
    for s, n in seqs.items():
        for l in range(n):
            expect[(s, l)] = pt.append_page(s, l)
    for (s, l), phys in expect.items():
        assert pt.lookup(s, l) == phys
    pm, ok = pt.lookup_batch(3, seqs[3])
    assert np.asarray(ok).all()
    np.testing.assert_array_equal(
        np.asarray(pm), [expect[(3, l)] for l in range(seqs[3])])
    freed = pt.release_sequence(3)
    assert freed == seqs[3]
    assert pt.lookup(3, 0) is None
    # pages are reusable after release
    p = pt.append_page(99, 0)
    assert pt.lookup(99, 0) == p
    assert pt.cn_bits_per_page() < 8.0  # the decoupling claim


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 6), st.integers(4, 24))
def test_page_tables_agree(n_seq, pages_per_seq):
    lt = LudoPageTable(4096)
    ct = CuckooPageTable(4096)
    for s in range(n_seq):
        for l in range(pages_per_seq):
            lt.append_page(s, l)
            ct.append_page(s, l)
    for s in range(n_seq):
        pm, ok = lt.lookup_batch(s, pages_per_seq)
        assert np.asarray(ok).all()
        pm2, sel = ct.lookup2_batch(s, pages_per_seq)
        for l in range(pages_per_seq):
            assert pm2[l, sel[l]] >= 0


_INT8_POD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, re
from repro.configs import TrainConfig, get_config
from repro.models.lm import LM
from repro.train import SyntheticLM, init_state, make_train_step
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("llama3.2-1b", reduced=True)
model = LM(cfg, mesh=None)  # GSPMD-auto inside the pod-manual region
params = model.init(0)
src = SyntheticLM(cfg.vocab_size, 32, 8)
batch = {k: jnp.asarray(v) for k, v in src.global_batch_at(0).items()}
with mesh:
    s0, m0 = jax.jit(make_train_step(model, TrainConfig(learning_rate=1e-3),
                                     mesh=None))(init_state(params), batch)
    t1 = TrainConfig(learning_rate=1e-3, grad_compression="int8")
    step1 = jax.jit(make_train_step(model, t1, mesh=mesh))
    s1, m1 = step1(init_state(params, compression=True), batch)
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-3
    d0 = np.asarray(jax.tree.leaves(s0.params)[0]
                    - jax.tree.leaves(params)[0], np.float32)
    d1 = np.asarray(jax.tree.leaves(s1.params)[0]
                    - jax.tree.leaves(params)[0], np.float32)
    cos = float((d0 * d1).sum()
                / (np.linalg.norm(d0) * np.linalg.norm(d1) + 1e-12))
    assert cos > 0.8, cos  # per-step int8 noise; error feedback carries rest
    ef = np.asarray(jax.tree.leaves(s1.ef)[0], np.float32)
    assert (np.abs(ef) > 0).any()  # residual populated
    txt = step1.lower(init_state(params, compression=True),
                      batch).compile().as_text()
    assert re.findall(r"s8\\[[\\d,]*\\][^\\n]*collective-permute", txt)
    print("INT8_POD_OK", round(cos, 3))
"""


@pytest.mark.slow
@pytest.mark.mesh
def test_int8_pod_gradient_compression_subprocess():
    """int8 inter-pod grad exchange: int8 on the wire, EF residual, update
    direction preserved — on a 2-pod fake mesh."""
    import os
    import subprocess
    import sys
    if tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5):
        pytest.skip("partial-auto shard_map (manual pod subgroup) aborts in "
                    "this jax/XLA build: Check failed IsManualSubgroup()")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _INT8_POD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "INT8_POD_OK" in out.stdout, out.stderr[-1500:]
