"""Scalar-vs-batched write parity: the batched ops ARE the protocol.

``insert_batch``/``update_batch``/``delete_batch`` must be *exact*
vectorisations of the scalar §4.3 walks: applying a shuffled op mix
scalarly and via the batch ops must leave identical MN state
(``mn_arrays``), identical CommMeter totals (byte-for-byte), and an
identical CN-cache — plus identical results lane-for-lane.  The same
contract flows up through ``OutbackStore`` (directory routing, frozen
buffering) and the ``repro.api`` stack, including CN-cache coherence
through a live §4.4 split.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import StoreSpec, open_store
from repro.core.cn_cache import CNKeyCache
from repro.core.hashing import splitmix64
from repro.core.outback import OutbackShard
from repro.core.store import OutbackStore, make_uniform_keys

N = 12_000


def _mix(n_ops, seed, n_keys=N, n_new=3000):
    """A shuffled insert/update/delete mix (existing, fresh + repeat keys)."""
    rng = np.random.default_rng(seed)
    keys = make_uniform_keys(n_keys, 5)
    new = splitmix64(np.arange(1, n_new + 1, dtype=np.uint64)
                     + np.uint64(77 << 40))
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.35:
            ops.append(("u", int(keys[rng.integers(n_keys)]),
                        int(rng.integers(1 << 30))))
        elif r < 0.65:
            ops.append(("i", int(new[rng.integers(n_new)]),
                        int(rng.integers(1 << 30))))
        elif r < 0.85:
            ops.append(("d", int(keys[rng.integers(n_keys)]), 0))
        else:  # deletes of maybe-absent keys (repeat-delete path)
            ops.append(("d", int(new[rng.integers(n_new)]), 0))
    return keys, ops


def _apply_scalar(sh, ops):
    for op, k, v in ops:
        if op == "u":
            sh.update(k, v)
        elif op == "i":
            sh.insert(k, v)
        else:
            sh.delete(k)


def _apply_batched(sh, ops):
    """Same stream, grouped into runs of consecutive same-type ops — the
    order-preserving batching a doorbell window performs."""
    i = 0
    while i < len(ops):
        j = i
        while j < len(ops) and ops[j][0] == ops[i][0]:
            j += 1
        ks = np.asarray([o[1] for o in ops[i:j]], np.uint64)
        vs = np.asarray([o[2] for o in ops[i:j]], np.uint64)
        if ops[i][0] == "u":
            sh.update_batch(ks, vs)
        elif ops[i][0] == "i":
            sh.insert_batch(ks, vs)
        else:
            sh.delete_batch(ks)
        i = j


def _shard_state(sh):
    return ([a.copy() for a in sh.mn_arrays()]
            + [sh.cn.seeds.copy(), sh.seeds_mn.copy(),
               np.int64(sh.n_keys), np.int64(sh.heap_top),
               np.sort(np.asarray(sh.overflow.items()[0]))])


def _assert_same_state(a, b):
    for x, y in zip(_shard_state(a), _shard_state(b)):
        np.testing.assert_array_equal(x, y)
    assert a.meter.snapshot() == b.meter.snapshot()


# --------------------------------------------------------------- shard level
@settings(deadline=None, max_examples=4)
@given(st.integers(0, 1000))
def test_shard_mix_parity(seed):
    keys, ops = _mix(1200, seed)
    vals = splitmix64(keys)
    a = OutbackShard(keys, vals, load_factor=0.88)
    b = OutbackShard(keys, vals, load_factor=0.88)
    _apply_scalar(a, ops)
    _apply_batched(b, ops)
    _assert_same_state(a, b)


def test_shard_mix_parity_with_cn_cache():
    keys, ops = _mix(1500, 42)
    vals = splitmix64(keys)
    a = OutbackShard(keys, vals, load_factor=0.88, cn_cache=CNKeyCache(1 << 16))
    b = OutbackShard(keys, vals, load_factor=0.88, cn_cache=CNKeyCache(1 << 16))
    # warm both caches identically so coherence notes have entries to touch
    a.get_batch(keys[:512])
    b.get_batch(keys[:512])
    _apply_scalar(a, ops)
    _apply_batched(b, ops)
    _assert_same_state(a, b)
    for x, y in zip(a.cn_cache.arrays(), b.cn_cache.arrays()):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(a.cn_cache.neg_arrays(), b.cn_cache.neg_arrays()):
        np.testing.assert_array_equal(x, y)


def test_shard_scalar_vs_batched_get_meters_identical():
    """Get accounting parity: n scalar Gets == one n-lane batched Get,
    present keys, absent keys and makeup lanes included."""
    keys = make_uniform_keys(4000, 3)
    vals = splitmix64(keys)
    absent = splitmix64(np.arange(1, 65, dtype=np.uint64) + np.uint64(9 << 41))
    q = np.concatenate([keys[:192], absent])
    a = OutbackShard(keys, vals, load_factor=0.9)
    b = OutbackShard(keys, vals, load_factor=0.9)
    for k in q:
        a.get(int(k))
    b.get_batch(q, resolve_makeup=True)
    assert a.meter.snapshot() == b.meter.snapshot()


def test_update_batch_duplicate_lanes_apply_in_order():
    keys = make_uniform_keys(256, 8)
    sh = OutbackShard(keys, splitmix64(keys), load_factor=0.8)
    k = keys[5]
    ok = sh.update_batch(np.asarray([k, k, k], np.uint64),
                         np.asarray([1, 2, 3], np.uint64))
    assert ok.all()
    assert sh.get(int(k)).value == 3  # last lane wins, like the scalar loop


def test_delete_batch_duplicate_lanes_second_misses():
    keys = make_uniform_keys(256, 8)
    sh = OutbackShard(keys, splitmix64(keys), load_factor=0.8)
    k = keys[7]
    ok = sh.delete_batch(np.asarray([k, k], np.uint64))
    assert ok.tolist() == [True, False]
    assert sh.get(int(k)).value is None


# --------------------------------------------------------------- store level
def test_store_mix_parity_below_resize():
    keys, ops = _mix(900, 17, n_keys=8000, n_new=500)
    keys = keys[:8000]
    vals = splitmix64(keys)
    a = OutbackStore(keys, vals, load_factor=0.85, initial_depth=1)
    b = OutbackStore(keys, vals, load_factor=0.85, initial_depth=1)
    _apply_scalar(a, ops)
    _apply_batched(b, ops)
    assert len(a.resize_events) == len(b.resize_events) == 0
    assert a.meter_total().snapshot() == b.meter_total().snapshot()
    for ta, tb in zip(a.tables, b.tables):
        _assert_same_state(ta, tb)


def test_store_insert_batch_triggers_split_and_stays_correct():
    keys = make_uniform_keys(10_000, 21)
    vals = splitmix64(keys)
    store = OutbackStore(keys, vals, load_factor=0.85)
    new = splitmix64(np.arange(1, 6001, dtype=np.uint64) + np.uint64(3 << 42))
    statuses = store.insert_batch(new, new >> np.uint64(3))
    assert len(store.resize_events) >= 1 and store.global_depth >= 1
    assert "frozen" not in statuses  # splits complete inside the batch
    v_lo, v_hi, match = store.get_batch(new, resolve_makeup=True)
    got = (np.asarray(v_hi, np.uint64) << np.uint64(32)) | np.asarray(v_lo, np.uint64)
    assert match.all()
    np.testing.assert_array_equal(got, new >> np.uint64(3))
    # the preload survived the split too
    _, _, m2 = store.get_batch(keys[::17], resolve_makeup=True)
    assert m2.all()


def test_store_frozen_window_buffers_batched_mutations():
    keys = make_uniform_keys(6000, 31)
    vals = splitmix64(keys)
    store = OutbackStore(keys, vals, load_factor=0.85)
    h = store.begin_split(0)
    new = splitmix64(np.arange(1, 33, dtype=np.uint64) + np.uint64(5 << 42))
    st_frozen = store.insert_batch(new, new)
    assert st_frozen == ["frozen"] * len(new)
    assert not store.delete_batch(keys[:8]).any()  # FALSE'd + buffered
    h.build()
    h.finish()
    _, _, match = store.get_batch(new, resolve_makeup=True)
    assert match.all()  # buffered inserts replayed after the swap
    # buffered deletes replayed too
    assert not store.get_batch(keys[:8], resolve_makeup=True)[2].any()


# ----------------------------------------------------------------- api level
def test_api_batched_mutations_match_scalar_loop():
    keys = make_uniform_keys(6000, 2)
    vals = splitmix64(keys)
    cand = splitmix64(np.arange(1, 257, dtype=np.uint64) + np.uint64(11 << 40))
    for kind in ("outback", "outback-dir", "race", "mica", "cluster", "dummy"):
        # keep only inserts the kind accepts (MICA/RACE/cluster bound
        # rejections raise identically on both paths; rejected inserts
        # leave the index unchanged, so the filtered replay is faithful)
        probe = open_store(StoreSpec(kind), keys, vals)
        new = []
        for k in cand:
            try:
                probe.insert(int(k), 1)
                new.append(int(k))
            except RuntimeError:
                pass
        new = np.asarray(new, np.uint64)
        assert new.size > 200, kind
        a = open_store(StoreSpec(kind), keys, vals)
        b = open_store(StoreSpec(kind), keys, vals)
        # scalar loop on a
        cases_a, ok_ua, ok_da = [], [], []
        for k in new:
            cases_a.append(a.insert(int(k), int(k) >> 3).status)
        for k in keys[:256]:
            ok_ua.append(bool(a.update(int(k), 9).found[0]))
        for k in keys[:64]:
            ok_da.append(bool(a.delete(int(k)).found[0]))
        # batched on b
        res_i = b.insert_batch(new, new >> np.uint64(3))
        res_u = b.update_batch(keys[:256], np.full(256, 9, np.uint64))
        res_d = b.delete_batch(keys[:64])
        assert list(res_i.statuses) == cases_a, kind
        assert res_u.found.tolist() == ok_ua, kind
        assert res_d.found.tolist() == ok_da, kind
        assert (a.meter_totals().snapshot()
                == b.meter_totals().snapshot()), kind
        # per-call attribution is stamped by the meter layer
        assert res_u.round_trips > 0 and res_u.req_bytes > 0


def test_api_stack_cache_coherent_through_batched_split():
    """Acceptance: batched writes through the full stack keep the CN cache
    coherent across a live §4.4 split."""
    keys = make_uniform_keys(9000, 4)
    vals = splitmix64(keys)
    spec = StoreSpec("outback-dir", load_factor=0.85,
                     cache_budget_bytes=64 << 10)
    store = open_store(spec, keys, vals)
    store.get_batch(keys[:2000])  # warm the cache
    store.get_batch(keys[:2000])
    new = splitmix64(np.arange(1, 5001, dtype=np.uint64) + np.uint64(13 << 42))
    store.insert_batch(new, new >> np.uint64(2))
    assert len(store.engine.resize_events) >= 1  # a split really happened
    # updates through the batch path refresh/invalidate cached entries
    store.update_batch(keys[:64], np.full(64, 123, np.uint64))
    res = store.get_batch(np.concatenate([keys[:64], new[:64]]))
    assert res.found.all()
    np.testing.assert_array_equal(np.asarray(res.values[:64]),
                                  np.full(64, 123, np.uint64))
    np.testing.assert_array_equal(np.asarray(res.values[64:]),
                                  (new[:64] >> np.uint64(2)))
    # deletes stay coherent too (no stale positive hit from the cache)
    store.delete_batch(keys[:8])
    assert not store.get_batch(keys[:8]).found.any()


def test_api_sharded_batched_mutations():
    keys = make_uniform_keys(4096, 6)
    vals = splitmix64(keys)
    st_ = open_store(StoreSpec("sharded", params={"num_shards": 2}),
                     keys, vals)
    new = []
    for k in splitmix64(np.arange(1, 200, dtype=np.uint64) + np.uint64(1 << 43)):
        try:  # displacement/fp bounds may reject a few; match scalar policy
            if bool(st_.insert(int(k), 1).found[0]):
                new.append(int(k))
        except RuntimeError:
            pass
    res = st_.update_batch(np.asarray(new, np.uint64),
                           np.full(len(new), 7, np.uint64))
    assert res.found.all()
    got = st_.get_batch(np.asarray(new, np.uint64))
    assert got.found.all()
    assert set(np.asarray(got.values).tolist()) == {7}
    res_d = st_.delete_batch(np.asarray(new[:16], np.uint64))
    assert res_d.found.all()
    assert not st_.get_batch(np.asarray(new[:16], np.uint64)).found.any()
